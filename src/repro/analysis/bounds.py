"""Static memlet bounds / volume checking and the donation lint.

``BND001`` — a memlet subset provably escapes its container under the
    map ranges binding its parameters (interval arithmetic over the
    scope's iteration box; unprovable dimensions stay silent).
``BND002`` — a transient is consumed outside the region any producer
    writes: the consumed interval hull escapes the produced hull in
    some dimension. Hulls over-approximate the produced region, so a
    finding is a proof that some read touches a never-written element.
``BND003`` — a memlet carries an explicit volume smaller than its
    subset's element count (the Fig.-7 consistency direction: the
    annotated movement cannot cover the annotated region).
``DON001``/``DON002`` — donation lints over ``metadata["donated"]``:
    a donated buffer that is never written lets XLA alias its storage
    to an output while readers still expect the old value (the PR-6/
    PR-8 bug class), and a donated name must be a program argument.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..core.sdfg import (AccessNode, MapEntry, MapExit, NestedSDFG, SDFG,
                         State, Tasklet)
from .affine import (container_extents, edge_scope, expr_bounds, param_box,
                     scope_map, static_env, subset_box)
from .diagnostics import Diagnostic


def _edge_label(e) -> str:
    return f"{getattr(e.src, 'label', type(e.src).__name__)}->" \
           f"{getattr(e.dst, 'label', type(e.dst).__name__)}"


def check_state_bounds(sdfg: SDFG, state: State,
                       env: Dict[str, int]) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    scope_of = scope_map(state)
    boxes: Dict[Optional[MapEntry], Dict] = {}
    for e in state.edges:
        m = e.memlet
        if m is None or m.data is None:
            continue
        desc = sdfg.arrays.get(m.data)
        if desc is None or not hasattr(desc, "shape"):
            continue
        extents = container_extents(sdfg, m.data, env)
        scope = edge_scope(e, scope_of)
        if scope not in boxes:
            boxes[scope] = param_box(scope, scope_of, env)[0]
        box = boxes[scope]
        scope_label = scope.map.label if scope is not None else None
        # BND001: per-dimension interval containment
        if m.subset is not None and extents is not None \
                and len(m.subset) == len(extents):
            for d, (r, ext) in enumerate(zip(m.subset, extents)):
                b_start = expr_bounds(r.start, box, env)
                b_stop = expr_bounds(r.stop, box, env)
                if b_start is not None and b_start[0] < 0:
                    diags.append(Diagnostic(
                        code="BND001",
                        message=(f"memlet {_edge_label(e)} subset dim {d} "
                                 f"reaches index {b_start[0]} < 0 in "
                                 f"'{m.data}'"),
                        state=state.label, scope=scope_label,
                        container=m.data))
                elif b_stop is not None and b_stop[1] - 1 >= ext:
                    diags.append(Diagnostic(
                        code="BND001",
                        message=(f"memlet {_edge_label(e)} subset dim {d} "
                                 f"reaches index {b_stop[1] - 1} >= extent "
                                 f"{ext} of '{m.data}'"),
                        state=state.label, scope=scope_label,
                        container=m.data))
        # BND003: explicit volume vs subset element count
        if m.volume is not None and m.subset is not None and not m.dynamic:
            try:
                vol = int(m.volume.subs(env).as_int())
                count = 1
                for r in m.subset:
                    count *= int(r.size.subs(env).as_int())
            except Exception:
                vol = count = None
            if vol is not None and vol < count:
                diags.append(Diagnostic(
                    code="BND003",
                    message=(f"memlet {_edge_label(e)} declares volume "
                             f"{vol} but its subset holds {count} "
                             f"elements of '{m.data}'"),
                    state=state.label, scope=scope_label,
                    container=m.data))
    return diags


# ---------------------------------------------------------------------------
# Transient produced-vs-consumed regions (BND002)
# ---------------------------------------------------------------------------


def _hull(a: Optional[Tuple], b: Tuple) -> Tuple:
    if a is None:
        return b
    return tuple((min(x[0], y[0]), max(x[1], y[1])) for x, y in zip(a, b))


def _tasklet_level_accesses(state: State, scope_of):
    """Yield (kind, edge, scope) at tasklet granularity — the same
    element-view selection the race checker uses."""
    for e in state.edges:
        m = e.memlet
        if m is None or m.data is None or m.subset is None:
            continue
        if isinstance(e.src, Tasklet) and isinstance(e.dst, Tasklet):
            continue
        if isinstance(e.src, MapEntry) and isinstance(e.dst, Tasklet):
            yield "read", e, edge_scope(e, scope_of)
        elif isinstance(e.src, AccessNode) and isinstance(e.dst, Tasklet):
            yield "read", e, edge_scope(e, scope_of)
        elif isinstance(e.src, Tasklet):
            yield "write", e, edge_scope(e, scope_of)


def check_transient_regions(sdfg: SDFG) -> List[Diagnostic]:
    env = static_env(sdfg)
    produced: Dict[str, Optional[Tuple]] = {}
    consumed: Dict[str, List] = {}
    opaque = set()   # transients with an unprovable producer: stay silent
    for state in sdfg.states:
        scope_of = scope_map(state)
        boxes: Dict[Optional[MapEntry], Dict] = {}
        for kind, e, scope in _tasklet_level_accesses(state, scope_of):
            name = e.memlet.data
            desc = sdfg.arrays.get(name)
            if desc is None or not getattr(desc, "transient", False) \
                    or not hasattr(desc, "shape"):
                continue
            extents = container_extents(sdfg, name, env)
            if extents is None or len(e.memlet.subset) != len(extents):
                opaque.add(name)
                continue
            if scope not in boxes:
                boxes[scope] = param_box(scope, scope_of, env)[0]
            sb = subset_box(e.memlet.subset, boxes[scope], env)
            if sb is None:
                opaque.add(name)
                continue
            if kind == "write":
                produced[name] = _hull(produced.get(name), sb)
            else:
                consumed.setdefault(name, []).append((state.label, sb))
    diags: List[Diagnostic] = []
    for name, uses in consumed.items():
        if name in opaque or name not in produced:
            continue
        phull = produced[name]
        for state_label, sb in uses:
            for d, ((rlo, rhi), (plo, phi)) in enumerate(zip(sb, phull)):
                if rlo < plo or rhi > phi:
                    diags.append(Diagnostic(
                        code="BND002",
                        message=(f"transient '{name}' consumed at dim {d} "
                                 f"interval [{rlo},{rhi}] outside the "
                                 f"produced region [{plo},{phi}]"),
                        state=state_label, container=name))
                    break
    return diags


# ---------------------------------------------------------------------------
# Donation lints (DON001/DON002)
# ---------------------------------------------------------------------------


def _written_containers(sdfg: SDFG) -> set:
    out = set()
    for state in sdfg.states:
        for e in state.edges:
            m = e.memlet
            if m is None or m.data is None:
                continue
            if isinstance(e.dst, (AccessNode, MapExit)) \
                    and not isinstance(e.src, (AccessNode, MapEntry)):
                out.add(m.data)
            elif isinstance(e.dst, AccessNode) and isinstance(e.src,
                                                              AccessNode):
                out.add(e.dst.data)   # copy edge
    return out


def check_donation(sdfg: SDFG) -> List[Diagnostic]:
    donated = sdfg.metadata.get("donated") or []
    if not donated:
        return []
    diags: List[Diagnostic] = []
    args = set(sdfg.argument_names())
    written = _written_containers(sdfg)
    for name in donated:
        if name not in args:
            diags.append(Diagnostic(
                code="DON002",
                message=(f"donated name '{name}' is not a program "
                         "argument (nothing to donate)"),
                container=name))
            continue
        if name not in written:
            diags.append(Diagnostic(
                code="DON001",
                message=(f"donated buffer '{name}' is never written: XLA "
                         "may alias its storage to an output while it is "
                         "still read"),
                container=name))
    return diags


def check_bounds(sdfg: SDFG) -> List[Diagnostic]:
    """All bounds/volume/donation diagnostics (recursing into nests)."""
    env = static_env(sdfg)
    diags: List[Diagnostic] = []
    for st in sdfg.states:
        diags.extend(check_state_bounds(sdfg, st, env))
        for n in st.nodes:
            if isinstance(n, NestedSDFG):
                diags.extend(check_bounds(n.sdfg))
    diags.extend(check_transient_regions(sdfg))
    diags.extend(check_donation(sdfg))
    return diags
