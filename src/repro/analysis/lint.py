"""Compile-and-verify lint over the repo's benchmark and model-zoo
programs.

``python -m repro.analysis.lint`` compiles every benchmark SDFG (and,
with ``--arch``, serving decode steps for reduced model-zoo configs)
through **both** backend pipelines with the verification harness armed,
and emits one machine-readable JSON report: per target/backend the
error-severity diagnostics (verifier findings, attributed to the
introducing pass where known) and the info-severity refusal stream.
Exit status is non-zero iff any error-severity diagnostic (or a
compile crash) was found — the CI gate.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import traceback
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

BACKENDS = ("jnp", "pallas")


def _benchmark_targets(bench_dir: Path) -> Dict[str, Callable]:
    """Name -> SDFG builder for every committed benchmark program."""
    if not bench_dir.is_dir():
        return {}
    sys.path.insert(0, str(bench_dir))
    try:
        axpydot = importlib.import_module("axpydot")
        gemver = importlib.import_module("gemver")
        jacobi = importlib.import_module("jacobi_chain")
        stencil = importlib.import_module("stencil_bench")
        lenet = importlib.import_module("lenet")
    except ImportError as exc:       # pragma: no cover - partial checkout
        print(f"lint: cannot import benchmarks from {bench_dir}: {exc}",
              file=sys.stderr)
        return {}
    return {
        "axpydot": lambda: axpydot.build(256),
        "axpydot_two_producer": lambda: axpydot.build_two_producer(256),
        "gemver": lambda: gemver.build(64),
        "gemver_chain": lambda: gemver.build_chain(64),
        "star_stencil": lambda: stencil._star_sdfg(64, 64),
        "jacobi_chain": lambda: jacobi._chain_sdfg(128),
        "lenet_convblock": lambda: lenet._convblock_sdfg(2),
    }


def _model_lowered(arch: str):
    """Lowered serving decode step for a reduced model-zoo config —
    exercises the donation metadata and (for the pallas pipeline) the
    grid/tiling annotation checks on a real multi-layer program."""
    import dataclasses

    import jax

    from ..configs import get_config
    from ..models.transformer import TransformerLM
    from ..serving.compile import DecodeStepCompiler

    cfg = dataclasses.replace(get_config(arch).reduced(),
                              activation_dtype="float32")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    compiler = DecodeStepCompiler(model, params, page_size=8, n_pages=16)
    return compiler._lowered(B=2, ctx=16), compiler


def _lint_one(name: str, make_lowered: Callable, backend: str,
              pipeline=None) -> dict:
    from ..pipeline.stages import Lowered

    entry = {"target": name, "backend": backend, "ok": True,
             "diagnostics": [], "refusals": [], "error": None}
    try:
        low = make_lowered()
        if not isinstance(low, Lowered):
            from ..pipeline import lower
            low = lower(low)
        cp = low.compile(backend=backend, cache=None, verify="full",
                         pipeline=pipeline)
        vrec = cp.report.get("verify") or {}
        diags = list(vrec.get("baseline", ()))
        for p in vrec.get("passes", ()):
            diags.extend(p.get("violations", ()))
        errors = [d for d in diags if d.get("severity", "error") == "error"]
        entry["diagnostics"] = errors
        entry["refusals"] = list(cp.report.get("refusals", ()))
        entry["ok"] = not errors
    except Exception as exc:
        entry["ok"] = False
        entry["error"] = f"{type(exc).__name__}: {exc}"
        entry["traceback"] = traceback.format_exc(limit=8)
    return entry


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="compile every benchmark (and selected model-zoo "
                    "configs) through both backend pipelines with the "
                    "static verifier armed")
    ap.add_argument("--benchmarks-dir", default="benchmarks",
                    help="directory holding the benchmark programs")
    ap.add_argument("--target", action="append", default=None,
                    help="restrict to named target(s)")
    ap.add_argument("--backend", choices=BACKENDS, default=None,
                    help="restrict to one backend (default: both)")
    ap.add_argument("--arch", action="append", default=None,
                    help="also lint the serving decode step of this "
                         "model-zoo arch (reduced config); repeatable")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here instead of stdout")
    args = ap.parse_args(argv)

    backends = (args.backend,) if args.backend else BACKENDS
    targets: List[Tuple[str, Callable, object]] = []
    for name, builder in _benchmark_targets(
            Path(args.benchmarks_dir)).items():
        targets.append((name, builder, None))
    for arch in (args.arch or ()):
        def make(arch=arch):
            low, _ = _model_lowered(arch)
            return low
        targets.append((f"decode_step[{arch}]", make, None))
    if args.target:
        keep = set(args.target)
        targets = [t for t in targets if t[0] in keep]
    if not targets:
        print("lint: no targets found", file=sys.stderr)
        return 2

    results = []
    for name, make, pipeline in targets:
        for backend in backends:
            if name.startswith("decode_step[") and backend == "pallas":
                # the decode step's pallas path uses the serving pipeline
                from ..serving.compile import decode_pipeline
                pl = decode_pipeline(True, False)
            else:
                pl = pipeline
            r = _lint_one(name, make, backend, pipeline=pl)
            results.append(r)
            status = "ok" if r["ok"] else "FAIL"
            detail = r["error"] or "; ".join(
                d["code"] for d in r["diagnostics"]) or ""
            print(f"lint: {name}/{backend}: {status} {detail}".rstrip(),
                  file=sys.stderr)

    report = {
        "targets": len(targets), "backends": list(backends),
        "failures": sum(not r["ok"] for r in results),
        "results": results,
    }
    text = json.dumps(report, indent=2, default=str)
    if args.out:
        Path(args.out).write_text(text + "\n")
    else:
        print(text)
    return 1 if report["failures"] else 0


if __name__ == "__main__":
    sys.exit(main())
