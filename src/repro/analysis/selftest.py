"""Seeded-mutation self-test for the verifier.

Each case builds a known-good SDFG, runs a (possibly empty) pass
pipeline with the verification harness armed, then applies one
deliberate miscompilation as a final ``Mutate[...]`` pass. The harness
must (a) report a clean baseline and clean legitimate passes, (b) catch
the mutation with the *expected* diagnostic code, and (c) attribute it
to the mutation pass — exactly the guarantee that lets a report reader
trust the "introduced by" field on a real pipeline bug.

Run directly (``python -m repro.analysis.selftest``) for a table, or
through ``tests/test_analysis.py`` in CI.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Callable, List, Optional

from ..core.memlet import Memlet, Range, Subset
from ..core.sdfg import SDFG, MapEntry, Tasklet
from ..core.symbolic import Expr, sym
from ..pipeline.passes import (GridConversionPass, MapTilingPass, Pass,
                               PassManager, ShardMapPass)


class _MutationPass(Pass):
    """Wraps one injected miscompilation as a pipeline pass so the
    harness's per-pass attribution has a name to pin it on."""

    def __init__(self, fn: Callable[[SDFG], object], label: str):
        self.fn = fn
        self.name = f"Mutate[{label}]"

    def apply(self, sdfg: SDFG, report: dict):
        return self.fn(sdfg)

    def options(self):
        return {"label": self.name}


# ---------------------------------------------------------------------------
# Known-good base programs (self-contained; no benchmark imports)
# ---------------------------------------------------------------------------


def vec_sdfg(n: int = 64, inplace: bool = False) -> SDFG:
    """y[i] = 2 x[i] over [0, n-1) (or x in place)."""
    s = SDFG("vec")
    s.add_array("x", (n,), "float32")
    if not inplace:
        s.add_array("y", (n,), "float32")
    st = s.add_state("main", is_start=True)
    out = "x" if inplace else "y"
    st.add_mapped_tasklet(
        "scale", {"i": (0, n - 1)},
        inputs={"xv": Memlet.simple("x", Subset([Range.index(sym("i"))]))},
        outputs={"yv": Memlet.simple(out,
                                     Subset([Range.index(sym("i"))]))},
        fn=lambda xv: {"yv": xv * 2.0})
    return s


def reduce_sdfg(n: int = 64) -> SDFG:
    """acc[0] += x[i] (wcr-protected whole-container accumulation)."""
    s = SDFG("reduce")
    s.add_array("x", (n,), "float32")
    s.add_array("acc", (1,), "float32")
    st = s.add_state("main", is_start=True)
    st.add_mapped_tasklet(
        "accum", {"i": (0, n)},
        inputs={"xv": Memlet.simple("x", Subset([Range.index(sym("i"))]))},
        outputs={"a": Memlet.simple("acc", wcr="add")},
        fn=lambda xv: {"a": xv.reshape(1)})
    return s


def chain_sdfg(n: int = 64) -> SDFG:
    """x -> t (transient) -> y, two maps over [0, n-1)."""
    s = SDFG("chain")
    s.add_array("x", (n,), "float32")
    s.add_transient("t", (n,), "float32")
    s.add_array("y", (n,), "float32")
    st = s.add_state("main", is_start=True)
    idx = lambda: Subset([Range.index(sym("i"))])
    st.add_mapped_tasklet(
        "produce", {"i": (0, n - 1)},
        inputs={"xv": Memlet.simple("x", idx())},
        outputs={"tv": Memlet.simple("t", idx())},
        fn=lambda xv: {"tv": xv * 2.0})
    st.add_mapped_tasklet(
        "consume", {"i": (0, n - 1)},
        inputs={"tv": Memlet.simple("t", idx())},
        outputs={"yv": Memlet.simple("y", idx())},
        fn=lambda tv: {"yv": tv + 1.0})
    return s


def mat_sdfg(n: int = 256, m: int = 256) -> SDFG:
    """2-D elementwise map, large enough to tile and grid-convert."""
    s = SDFG("mat")
    s.add_array("a", (n, m), "float32")
    s.add_array("b", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    sub = lambda: Subset([Range.index(sym("i")), Range.index(sym("j"))])
    st.add_mapped_tasklet(
        "ew", {"i": (0, n), "j": (0, m)},
        inputs={"av": Memlet.simple("a", sub())},
        outputs={"bv": Memlet.simple("b", sub())},
        fn=lambda av: {"bv": av * 3.0})
    return s


def rows_sdfg(n: int = 8, m: int = 4) -> SDFG:
    """Shardable row map with a psum accumulator (mirrors the shard-map
    test fixture): y[i, :] = 2 x[i, :], acc += sum(x[i, :])."""
    s = SDFG("rows")
    s.add_array("x", (n, m), "float32")
    s.add_array("y", (n, m), "float32")
    s.add_array("acc", (1,), "float32")
    st = s.add_state("main", is_start=True)
    row = lambda: Subset([Range.index(sym("i")), Range.make(0, m)])
    st.add_mapped_tasklet(
        "rows", {"i": (0, n)},
        inputs={"xr": Memlet.simple("x", row())},
        outputs={"yr": Memlet.simple("y", row()),
                 "a": Memlet.simple("acc", wcr="add")},
        fn=lambda xr: {"yr": xr * 2.0, "a": xr.sum().reshape(1)})
    return s


# ---------------------------------------------------------------------------
# Edge finders
# ---------------------------------------------------------------------------


def _find_edge(sdfg: SDFG, pred):
    for st in sdfg.states:
        for e in st.edges:
            if pred(e):
                return e
    raise AssertionError("selftest: no edge matches the mutation target")


def _write_edge(sdfg: SDFG, data: str):
    return _find_edge(sdfg, lambda e: e.memlet is not None
                      and e.memlet.data == data
                      and isinstance(e.src, Tasklet))


def _read_edge(sdfg: SDFG, data: str):
    return _find_edge(sdfg, lambda e: e.memlet is not None
                      and e.memlet.data == data
                      and isinstance(e.dst, Tasklet))


def _shard_meta(sdfg: SDFG) -> dict:
    from ..transforms.shard_map import SHARD_ANNOTATION
    meta = sdfg.metadata.get(SHARD_ANNOTATION)
    assert meta, "selftest: base program did not shard"
    return meta


# ---------------------------------------------------------------------------
# The mutations
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Case:
    name: str
    expected_code: str
    build: Callable[[], SDFG]
    mutate: Callable[[SDFG], object]
    passes: Callable[[], List[Pass]] = lambda: []


def _drop_wcr(sdfg):
    # the aggregated exit->access edge restates the wcr: drop every copy,
    # as a buggy transform rebuilding the scope would
    hit = 0
    for st in sdfg.states:
        for e in st.edges:
            if e.memlet is not None and e.memlet.wcr is not None:
                e.memlet.wcr = None
                hit += 1
    assert hit, "selftest: no wcr edge to drop"
    return f"dropped wcr on {hit} edge(s)"


def _shift_producer(sdfg):
    e = _write_edge(sdfg, "t")
    e.memlet.subset = Subset([Range.index(sym("i") + 1)])
    return "t[i] -> t[i+1]"


def _oob_read(sdfg):
    e = _read_edge(sdfg, "x")
    e.memlet.subset = Subset([Range.index(sym("i") + 2)])
    return "x[i] -> x[i+2] (reaches n past the extent)"


def _shrink_volume(sdfg):
    e = _write_edge(sdfg, "y")
    e.memlet.volume = Expr.const(0)
    return "volume 0 under a 1-element subset"


def _shift_inplace_read(sdfg):
    e = _read_edge(sdfg, "x")
    e.memlet.subset = Subset([Range.index(sym("i") + 1)])
    return "in-place read x[i] -> x[i+1]"


def _rogue_state(sdfg):
    st2 = sdfg.add_state("rogue")       # no interstate edge: unordered
    t = st2.add_tasklet("clobber", [], ["o"],
                        fn=lambda: {"o": 0.0})
    acc = st2.add_access("y")
    st2.add_edge(t, "o", acc, None,
                 Memlet.simple("y", Subset([Range.make(0, 1)])))
    return "unordered state writes y"


def _desync_tiling(sdfg):
    for st in sdfg.states:
        for node in st.nodes:
            if isinstance(node, MapEntry) \
                    and node.map.annotations.get("tiling"):
                for info in node.map.annotations["tiling"].values():
                    if isinstance(info, dict):
                        info["tile"] = int(info["tile"]) + 1
                        return f"tile+1 on {node.map.label}"
    raise AssertionError("selftest: no tiled map to desync")


def _desync_grid(sdfg):
    from ..codegen.pallas_backend import GRID_ANNOTATION
    for st in sdfg.states:
        for node in st.nodes:
            if isinstance(node, MapEntry):
                spec = node.map.annotations.get(GRID_ANNOTATION)
                if spec is not None and spec.grid:
                    p, size = spec.grid[0]
                    doctored = dataclasses.replace(
                        spec, grid=((p, size + 1),) + spec.grid[1:])
                    node.map.annotations[GRID_ANNOTATION] = doctored
                    return f"grid dim {p}: {size} -> {size + 1}"
    raise AssertionError("selftest: no grid-converted map to desync")


def _misclassify_replicated(sdfg):
    _shard_meta(sdfg)["specs"]["y"] = None
    return "y: sharded -> replicated"


def _misclassify_dim(sdfg):
    _shard_meta(sdfg)["specs"]["x"] = 7
    return "x: dim 0 -> dim 7"


def _orphan_psum(sdfg):
    meta = _shard_meta(sdfg)
    assert "acc" in meta["psum"]
    hit = 0
    for st in sdfg.states:
        for e in st.edges:
            if e.memlet is not None and e.memlet.data == "acc" \
                    and e.memlet.wcr is not None:
                e.memlet.wcr = None
                hit += 1
    assert hit, "selftest: no acc wcr edge"
    return "acc psum without wcr"


def _donate_readonly(sdfg):
    sdfg.metadata["donated"] = ["x"]
    return "donated read-only x"


def _donate_ghost(sdfg):
    sdfg.metadata["donated"] = ["ghost"]
    return "donated unknown name"


CASES: List[Case] = [
    Case("wcr_drop", "RACE001", reduce_sdfg, _drop_wcr),
    Case("memlet_shift", "BND002", chain_sdfg, _shift_producer),
    Case("oob_subset", "BND001", vec_sdfg, _oob_read),
    Case("volume_mismatch", "BND003", vec_sdfg, _shrink_volume),
    Case("read_write_race", "RACE002",
         lambda: vec_sdfg(inplace=True), _shift_inplace_read),
    Case("interstate_race", "RACE003", vec_sdfg, _rogue_state),
    Case("tiling_desync", "ANN001", mat_sdfg, _desync_tiling,
         lambda: [MapTilingPass()]),
    Case("grid_desync", "ANN002", mat_sdfg, _desync_grid,
         lambda: [MapTilingPass(), GridConversionPass()]),
    Case("shard_misclassify", "SHD003", rows_sdfg, _misclassify_replicated,
         lambda: [ShardMapPass(n_shards=2)]),
    Case("shard_bad_dim", "SHD001", rows_sdfg, _misclassify_dim,
         lambda: [ShardMapPass(n_shards=2)]),
    Case("psum_no_wcr", "SHD002", rows_sdfg, _orphan_psum,
         lambda: [ShardMapPass(n_shards=2)]),
    Case("donation_alias", "DON001", vec_sdfg, _donate_readonly),
    Case("donation_unknown", "DON002", vec_sdfg, _donate_ghost),
]


def run_case(case: Case) -> dict:
    """Run one case; the returned record is what the tests assert on."""
    sdfg = case.build()
    pm = PassManager(case.passes(), name=f"selftest_{case.name}")
    pm.append(_MutationPass(case.mutate, case.name))
    report: dict = {}
    pm.run(sdfg, report=report, verify="full")
    vrec = report["verify"]
    mut_entry = vrec["passes"][-1]
    codes = sorted({v["code"] for v in mut_entry["violations"]})
    return {
        "name": case.name,
        "expected": case.expected_code,
        "caught": case.expected_code in codes,
        "codes": codes,
        "attributed_to": mut_entry["name"],
        "attribution_ok": mut_entry["name"].startswith("Mutate["),
        "baseline_clean": not vrec["baseline"],
        "prior_passes_clean": all(p["clean"] for p in vrec["passes"][:-1]),
    }


def run_all() -> List[dict]:
    return [run_case(c) for c in CASES]


def main() -> int:
    ok = True
    for r in run_all():
        good = (r["caught"] and r["baseline_clean"]
                and r["prior_passes_clean"])
        ok &= good
        print(f"{'PASS' if good else 'FAIL'}  {r['name']:<20} "
              f"expected {r['expected']:<8} got {','.join(r['codes']) or '-'}"
              f"  (attributed to {r['attributed_to']})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
