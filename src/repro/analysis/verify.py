"""The verifier: one entry point over all static checks, plus the
snapshot/diff helpers the :class:`~repro.pipeline.passes.PassManager`
harness uses to attribute new violations to the pass that introduced
them.

``verify_sdfg`` is pure — it never mutates the SDFG and never raises
on a finding (strictness is the harness's job via
:class:`~repro.analysis.diagnostics.VerificationError`).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..core.sdfg import MapEntry, NestedSDFG, SDFG
from ..core.validation import ValidationError, validate_sdfg
from .annotations import check_annotations
from .bounds import check_bounds
from .diagnostics import Diagnostic
from .race import check_races


def check_structure(sdfg: SDFG) -> List[Diagnostic]:
    """Run the raising core validator and fold failures into the
    diagnostic stream (STRUCT000; the named STRUCT001/STRUCT002 checks
    live in ``core.validation`` itself and surface through here)."""
    try:
        validate_sdfg(sdfg)
    except ValidationError as exc:
        code = getattr(exc, "code", None) or "STRUCT000"
        return [Diagnostic(code=code, message=str(exc))]
    return []


def verify_sdfg(sdfg: SDFG) -> List[Diagnostic]:
    """All error-severity findings for an SDFG, deterministic order."""
    diags: List[Diagnostic] = []
    diags.extend(check_structure(sdfg))
    diags.extend(check_races(sdfg))
    diags.extend(check_bounds(sdfg))
    diags.extend(check_annotations(sdfg))
    return diags


# ---------------------------------------------------------------------------
# Structural snapshots (the harness's per-pass state diff)
# ---------------------------------------------------------------------------


def snapshot(sdfg: SDFG) -> Dict:
    """Cheap structural fingerprint of the SDFG: containers, per-state
    node/edge counts, map annotations, metadata keys. The harness diffs
    consecutive snapshots so a report reader can see *what* a pass
    changed next to any violation it introduced."""
    containers = {}
    for name, desc in sdfg.arrays.items():
        containers[name] = (
            type(desc).__name__,
            tuple(repr(s) for s in (getattr(desc, "shape", ()) or ())),
            bool(getattr(desc, "transient", False)),
            getattr(getattr(desc, "storage", None), "value", None),
        )
    states = {}
    annotations = {}
    for st in sdfg.states:
        states[st.label] = (len(st.nodes), len(st.edges))
        for n in st.nodes:
            if isinstance(n, MapEntry):
                annotations[f"{st.label}/{n.map.label}"] = tuple(
                    sorted(n.map.annotations))
            elif isinstance(n, NestedSDFG):
                inner = snapshot(n.sdfg)
                for k, v in inner["annotations"].items():
                    annotations[f"{st.label}/{n.label}/{k}"] = v
    return {
        "containers": containers,
        "states": states,
        "annotations": annotations,
        "metadata": tuple(sorted(k for k in sdfg.metadata
                                 if k != "transformation_history")),
    }


def diff_snapshots(before: Dict, after: Dict) -> Dict:
    """{section: {added: [...], removed: [...], changed: [...]}} with
    empty sections omitted — ``{}`` means the pass was structurally a
    no-op at this granularity."""
    out: Dict = {}
    for section in ("containers", "states", "annotations"):
        b, a = before.get(section, {}), after.get(section, {})
        added = sorted(set(a) - set(b))
        removed = sorted(set(b) - set(a))
        changed = sorted(k for k in set(a) & set(b) if a[k] != b[k])
        if added or removed or changed:
            out[section] = {"added": added, "removed": removed,
                            "changed": changed}
    bm, am = set(before.get("metadata", ())), set(after.get("metadata", ()))
    if bm != am:
        out["metadata"] = {"added": sorted(am - bm),
                           "removed": sorted(bm - am), "changed": []}
    return out
