"""Composable model layers (pure-functional JAX, sharding-friendly).

Every perf-critical op routes through a dual-path selector — ``xla`` (jnp
composite, GSPMD-shardable: used by the multi-pod dry-run) or ``pallas``
(explicit-VMEM kernel, validated in interpret mode on CPU, the TPU
production path) — the LM-framework incarnation of the paper's multi-level
Library-Node expansion (DESIGN.md §3.4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def psc(x, *roles):
    """Activation sharding constraint by role, against the ambient mesh.

    roles per dim: 'batch' (shard over pod+data axes), 'model', 'seq_model'
    (sequence over model — long-context decode), or None. Filters to axes
    present in the ambient mesh and checks divisibility, so model code is
    mesh-agnostic; a no-op without a mesh context (CPU smoke tests).
    """
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if am is None or getattr(am, "empty", True):
        return x
    sizes = dict(am.shape)
    spec = []
    for dim, role in zip(x.shape, roles):
        if role == "batch":
            axes, prod = [], 1
            for a in ("pod", "data"):
                if a in sizes and dim % (prod * sizes[a]) == 0:
                    axes.append(a)
                    prod *= sizes[a]
            spec.append(tuple(axes) if len(axes) > 1 else
                        (axes[0] if axes else None))
        elif role in ("model", "seq_model"):
            spec.append("model" if "model" in sizes
                        and dim % sizes["model"] == 0 else None)
        else:
            spec.append(None)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)             # (..., S, 1, Dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window), dual-path
# ---------------------------------------------------------------------------
def _gqa_repeat(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def attention_xla(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                  q_offset=0):
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh). GSPMD-shardable einsum
    formulation; supports decode (Sq=1 with KV cache) via q_offset.

    Sharding: heads over 'model' when divisible; for decode with few KV
    heads the *sequence* dim of K/V shards over 'model' instead
    (sequence-parallel attention — GSPMD inserts the partial-softmax
    combine, the chip-level version of the paper's §3.3.1 partial sums)."""
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    n_rep = hq // hkv
    decode = sq == 1
    # decode with few KV heads: keep K/V sequence-sharded (matches the
    # cache sharding rule) so no per-step cache reshard is needed
    seq_sharded = decode and hkv % _model_size() != 0
    k = _gqa_repeat(k, n_rep)
    v = _gqa_repeat(v, n_rep)
    if seq_sharded:
        k = psc(k, "batch", "seq_model", None, None)
        v = psc(v, "batch", "seq_model", None, None)
    else:
        q = psc(q, "batch", None, "model", None)
        k = psc(k, "batch", None, "model", None)
        v = psc(v, "batch", None, "model", None)
    scale = 1.0 / np.sqrt(dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if seq_sharded:
        logits = psc(logits, "batch", None, None, "seq_model")
    else:
        logits = psc(logits, "batch", "model", None, None)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    out = psc(out, "batch", None, "model", None)
    return out.astype(q.dtype)


def _model_size() -> int:
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not getattr(am, "empty", True):
            return dict(am.shape).get("model", 1)
    except Exception:
        pass
    return 1


def attention_chunked(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, q_offset=0,
                      bk: int = 1024):
    """Online-softmax chunked attention (beyond-paper optimization,
    EXPERIMENTS §Perf): the (Sq, Sk) score matrix never materializes —
    KV streams through in bk-chunks with running (max, sum, acc) carried
    across a scan, the XLA-level realization of the flash/streaming-
    composition insight. When the head count does not divide the model
    axis (yi-34b: 56 heads on 16), queries shard over *sequence* instead
    (sequence parallelism) so compute still spreads across all chips."""
    from . import _flags
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    n_rep = hq // hkv
    k = _gqa_repeat(k, n_rep)
    v = _gqa_repeat(v, n_rep)
    heads_shard = hq % _model_size() == 0
    if heads_shard:
        q = psc(q, "batch", None, "model", None)
        k = psc(k, "batch", None, "model", None)
        v = psc(v, "batch", None, "model", None)
    else:
        q = psc(q, "batch", "seq_model", None, None)  # SP over queries
    scale = 1.0 / np.sqrt(dh)
    bk = min(bk, sk)
    while sk % bk:
        bk -= 1
    n_chunks = sk // bk
    q32 = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, ci):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ci * bk, bk, axis=1
                                          ).astype(jnp.float32)
        vs = jax.lax.dynamic_slice_in_dim(v, ci * bk, bk, axis=1
                                          ).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, ks)
        k_pos = ci * bk + jnp.arange(bk)
        mask = jnp.ones((sq, bk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vs)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = jnp.zeros((b, hq, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), jnp.arange(n_chunks),
        unroll=n_chunks if _flags.UNROLL_SCANS else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)  # (b, sq, hq, dh)
    if heads_shard:
        out = psc(out, "batch", None, "model", None)
    else:
        out = psc(out, "batch", "seq_model", None, None)
    return out.astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, q_offset=0,
              impl: str = "xla", interpret: bool = True):
    if impl == "xla" or q.shape[1] == 1:
        return attention_xla(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
    if impl == "pallas":
        from ..kernels.attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------
def swiglu(x, w_gate, w_up, w_down):
    g = psc(jnp.einsum("bsd,df->bsf", x, w_gate), "batch", None, "model")
    u = psc(jnp.einsum("bsd,df->bsf", x, w_up), "batch", None, "model")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = psc(jnp.einsum("bsd,df->bsf", x, w_in) + b_in, "batch", None, "model")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Mixture of Experts: GShard-style capacity-based dispatch (static shapes,
# EP-shardable over the 'model' axis). Top-k routing with optional shared
# expert.
# ---------------------------------------------------------------------------
def moe_block(x, router_w, w_gate, w_up, w_down, *, top_k: int,
              capacity_factor: float = 1.25,
              shared: Optional[dict] = None, dispatch: str = "onehot",
              drop_tokens: bool = True):
    """x: (B, S, D); router_w: (D, E); expert weights stacked (E, D, F) /
    (E, F, D). Returns (out, aux_loss).

    dispatch='onehot' is the paper-era GShard formulation (one-hot
    einsums: O(T^2) dispatch FLOPs — the dry-run exposes this);
    dispatch='sort' is the beyond-paper scatter/gather dispatch
    (EXPERIMENTS §Perf): O(T*k*D) data movement, no dispatch matmuls.

    drop_tokens=False is eval mode: capacity = n_tokens, so no (token,
    expert) pair can overflow its buffer (top-k experts are distinct, so
    an expert receives at most n_tokens assignments). Dropping depends on
    whole-batch whole-sequence token counts, which token-by-token decode
    cannot see — disabling it makes decode match forward bit-for-bit.
    Cost caveat: capacity grows from ~top_k*cf/E * n_tokens to n_tokens,
    an E/(top_k*cf) constant inflation of the (E, C, D) expert buffers
    (and of the already-O(T*C) one-hot dispatch tensors) — for long-
    sequence eval at scale prefer dispatch='sort' or pass
    drop_tokens=True explicitly and accept train-style dropping."""
    b, s, d = x.shape
    e = router_w.shape[1]
    n_tokens = b * s
    xt = x.reshape(n_tokens, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts_idx = jax.lax.top_k(probs, top_k)       # (T, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    if drop_tokens:
        capacity = int(np.ceil(top_k * n_tokens * capacity_factor / e))
        capacity = max(capacity, 4)
    else:
        capacity = n_tokens

    # position of each (token, k) pair within its expert's buffer
    onehot = jax.nn.one_hot(experts_idx, e, dtype=jnp.int32)   # (T, k, E)
    flat = onehot.reshape(n_tokens * top_k, e)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1        # (T*k, E)
    pos = jnp.max(pos_in_expert, axis=-1).reshape(n_tokens, top_k)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    if dispatch == "sort":
        out = _moe_apply_scatter(xt, experts_idx, pos, keep, gate_vals,
                                 w_gate, w_up, w_down, e, capacity, d)
        if shared is not None:
            out = out + swiglu(xt[None], shared["w_gate"], shared["w_up"],
                               shared["w_down"])[0]
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(experts_idx[:, 0], e,
                                     dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        return out.reshape(b, s, d), aux

    # dispatch: (T, k, E, C) combine tensor (bool) - classic GShard einsums
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                            dtype=xt.dtype)[..., :capacity]    # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->etc", onehot.astype(xt.dtype), pos_oh)
    dispatch = psc(dispatch, "model", "batch", None)
    expert_in = psc(jnp.einsum("etc,td->ecd", dispatch, xt),
                    "model", None, None)                       # (E, C, D)

    # expert FFNs (EP: the leading expert dim shards over 'model')
    g = psc(jnp.einsum("ecd,edf->ecf", expert_in, w_gate), "model", None, None)
    u = psc(jnp.einsum("ecd,edf->ecf", expert_in, w_up), "model", None, None)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    expert_out = psc(jnp.einsum("ecf,efd->ecd", h, w_down),
                     "model", None, None)                      # (E, C, D)

    combine = jnp.einsum("tke,tkc,tk->etc", onehot.astype(xt.dtype), pos_oh,
                         gate_vals.astype(xt.dtype))
    out = jnp.einsum("etc,ecd->td", combine, expert_out)

    if shared is not None:
        out = out + swiglu(xt[None], shared["w_gate"], shared["w_up"],
                           shared["w_down"])[0]

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(experts_idx[:, 0], e, dtype=jnp.float32),
                  axis=0)
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux


def _moe_apply_scatter(xt, experts_idx, pos, keep, gate_vals,
                       w_gate, w_up, w_down, e, capacity, d):
    """Scatter/gather MoE dispatch: tokens scatter into (E*C, D) expert
    buffers by (expert, slot) index; outputs gather back. Slots are unique
    by construction (pos is a per-expert running count), so scatter-set is
    exact. Data movement O(T*k*D); no quadratic one-hot matmuls."""
    n_tokens, top_k = experts_idx.shape
    slot = experts_idx * capacity + pos                  # (T, k)
    slot = jnp.where(keep, slot, e * capacity)           # dropped -> sink row
    flat_slot = slot.reshape(-1)
    src = jnp.broadcast_to(xt[:, None, :], (n_tokens, top_k, d)
                           ).reshape(n_tokens * top_k, d)
    buf = jnp.zeros((e * capacity + 1, d), xt.dtype)
    buf = buf.at[flat_slot].set(src)
    expert_in = psc(buf[:-1].reshape(e, capacity, d), "model", None, None)

    g = psc(jnp.einsum("ecd,edf->ecf", expert_in, w_gate), "model", None,
            None)
    u = psc(jnp.einsum("ecd,edf->ecf", expert_in, w_up), "model", None, None)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    expert_out = psc(jnp.einsum("ecf,efd->ecd", h, w_down),
                     "model", None, None)

    flat_out = jnp.concatenate(
        [expert_out.reshape(e * capacity, d),
         jnp.zeros((1, d), xt.dtype)], axis=0)
    gathered = flat_out[flat_slot].reshape(n_tokens, top_k, d)
    out = jnp.sum(gathered * gate_vals[..., None].astype(xt.dtype), axis=1)
    return out


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
