"""LM model stack: composable layer blocks + the 10 assigned architectures."""
from .registry import build_model, example_batch, input_specs
from .transformer import TransformerLM
from .encdec import EncDecLM

__all__ = ["build_model", "example_batch", "input_specs", "TransformerLM",
           "EncDecLM"]
