"""Shared trace-time flags (module to avoid circular imports).

UNROLL_SCANS: set by the dry-run cost probes so every lax.scan unrolls and
XLA cost_analysis counts all iterations (while bodies count once).
"""
UNROLL_SCANS = False
