"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, T_enc, D) from ``input_specs``. Encoder:
bidirectional self-attention stack; decoder: causal self-attention +
cross-attention + FFN. Decode caches both the self-attn KV and the
projected encoder memory K/V.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import _flags, blocks
from .layers import dense_init, layer_norm, rms_norm


class EncDecLM:
    def __init__(self, cfg: ModelConfig, remat: bool = False):
        self.cfg = cfg
        self.remat = remat
        # Megatron-style vocab padding (see TransformerLM)
        self.vocab_padded = -(-cfg.vocab // 256) * 256

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.remat else fn

    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        dt = jnp.dtype(cfg.param_dtype)
        params: Dict = {
            "embed": dense_init(ks[0], (self.vocab_padded, cfg.d_model),
                                scale=1.0, dtype=dt),
            "frame_proj": dense_init(ks[1], (cfg.d_model, cfg.d_model),
                                     dtype=dt),
        }

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"attn": blocks.attn_init(cfg, k1),
                    "ffn": blocks.ffn_init(cfg, k2, False)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"attn": blocks.attn_init(cfg, k1),
                    "cross": blocks.attn_init(cfg, k2),
                    "ffn": blocks.ffn_init(cfg, k3, False)}

        params["encoder"] = jax.vmap(enc_layer)(
            jax.random.split(ks[2], cfg.n_encoder_layers))
        params["decoder"] = jax.vmap(dec_layer)(
            jax.random.split(ks[3], cfg.n_layers))
        params["final_scale"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.norm == "layernorm":
            params["final_bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
        params["lm_head"] = dense_init(
            ks[4], (cfg.d_model, self.vocab_padded), dtype=dt)
        return params

    # ------------------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        x = jnp.einsum("btd,de->bte", frames.astype(adt),
                       params["frame_proj"].astype(adt))

        def body(x, p):
            def blk(p_, x_):
                x_, _ = blocks.attn_apply(cfg, p_["attn"], x_, window=None,
                                          causal=False)
                x_, _ = blocks.ffn_apply(cfg, p_["ffn"], x_, False)
                return x_
            return self._maybe_remat(blk)(p, x), None

        x, _ = jax.lax.scan(body, x, params["encoder"],
                            unroll=self.cfg.n_encoder_layers
                            if _flags.UNROLL_SCANS else 1)
        return x

    def _cross_kv(self, cfg, p, memory):
        adt = jnp.dtype(cfg.activation_dtype)
        b, t, _ = memory.shape
        hd = cfg.head_dim
        k = jnp.einsum("btd,dh->bth", memory, p["wk"].astype(adt)
                       ).reshape(b, t, cfg.n_kv_heads, hd)
        v = jnp.einsum("btd,dh->bth", memory, p["wv"].astype(adt)
                       ).reshape(b, t, cfg.n_kv_heads, hd)
        return k, v

    def _decoder_stack(self, params, x, memory, cache=None, pos=None):
        cfg = self.cfg

        def body(carry, xs):
            x = carry
            p = xs[0]
            c = xs[1] if cache is not None else None
            if c is None:
                def blk(p_, x_, mem_):
                    x_, _ = blocks.attn_apply(cfg, p_["attn"], x_,
                                              window=None)
                    kv_ = self._cross_kv(cfg, p_["cross"], mem_)
                    x_, _ = blocks.attn_apply(cfg, p_["cross"], x_,
                                              window=None, kv_override=kv_)
                    x_, _ = blocks.ffn_apply(cfg, p_["ffn"], x_, False)
                    return x_
                return self._maybe_remat(blk)(p, x, memory), {}
            ac = {"k": c["k"], "v": c["v"], "pos": pos}
            x, nc = blocks.attn_apply(cfg, p["attn"], x, window=None,
                                      cache=ac)
            kv = (c["ck"], c["cv"])
            x, _ = blocks.attn_apply(cfg, p["cross"], x, window=None,
                                     kv_override=kv)
            x, _ = blocks.ffn_apply(cfg, p["ffn"], x, False)
            new_c = {"k": nc["k"], "v": nc["v"], "ck": kv[0], "cv": kv[1]}
            return x, new_c

        if cache is not None:
            x, new_caches = jax.lax.scan(body, x,
                                         (params["decoder"], cache["layers"]))
            return x, new_caches
        x, _ = jax.lax.scan(body, x, (params["decoder"],),
                            unroll=self.cfg.n_layers
                            if _flags.UNROLL_SCANS else 1)
        return x, None

    def _final(self, params, x):
        cfg = self.cfg
        if cfg.norm == "rmsnorm":
            x = rms_norm(x, params["final_scale"])
        else:
            x = layer_norm(x, params["final_scale"] + 1.0,
                           params["final_bias"])
        adt = jnp.dtype(cfg.activation_dtype)
        from .layers import psc
        logits = jnp.einsum("bsd,dv->bsv", x.astype(adt),
                            params["lm_head"].astype(adt))
        logits = psc(logits, "batch", None, "model")
        if self.vocab_padded != cfg.vocab:
            pad_mask = jnp.arange(self.vocab_padded) >= cfg.vocab
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                               logits)
        return logits

    def forward(self, params, batch: Dict):
        memory = self.encode(params, batch["frames"])
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(
            jnp.dtype(self.cfg.activation_dtype))
        x, _ = self._decoder_stack(params, x, memory)
        return self._final(params, x), jnp.zeros((), jnp.float32)

    def loss(self, params, batch: Dict):
        logits, _ = self.forward(params, batch)
        targets = batch["tokens"][:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - tgt)

    # -- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16,
                   t_enc: int = None) -> Dict:
        cfg = self.cfg
        t_enc = t_enc or cfg.n_stub_tokens
        hd = cfg.head_dim
        L = cfg.n_layers

        def zeros(shape):
            return jnp.zeros(shape, dtype)

        return {
            "pos": jnp.zeros((), jnp.int32),
            "layers": {
                "k": zeros((L, batch, max_seq, cfg.n_kv_heads, hd)),
                "v": zeros((L, batch, max_seq, cfg.n_kv_heads, hd)),
                "ck": zeros((L, batch, t_enc, cfg.n_kv_heads, hd)),
                "cv": zeros((L, batch, t_enc, cfg.n_kv_heads, hd)),
            },
        }

    def prefill_cache(self, params, cache, frames):
        """Project encoder memory into per-layer cross K/V."""
        cfg = self.cfg
        memory = self.encode(params, frames)

        def per_layer(p):
            return self._cross_kv(cfg, p["cross"], memory)

        ck, cv = jax.vmap(per_layer)(params["decoder"])
        cache["layers"]["ck"] = ck.astype(cache["layers"]["ck"].dtype)
        cache["layers"]["cv"] = cv.astype(cache["layers"]["cv"].dtype)
        return cache

    def decode_step(self, params, cache: Dict, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(
            jnp.dtype(cfg.activation_dtype))
        pos = cache["pos"]

        def body(x, xs):
            p, c = xs
            ac = {"k": c["k"], "v": c["v"], "pos": pos}
            x, nc = blocks.attn_apply(cfg, p["attn"], x, window=None,
                                      cache=ac)
            kv = (c["ck"].astype(x.dtype), c["cv"].astype(x.dtype))
            x, _ = blocks.attn_apply(cfg, p["cross"], x, window=None,
                                     kv_override=kv)
            x, _ = blocks.ffn_apply(cfg, p["ffn"], x, False)
            return x, {"k": nc["k"], "v": nc["v"], "ck": c["ck"],
                       "cv": c["cv"]}

        x, new_layers = jax.lax.scan(body, x,
                                     (params["decoder"], cache["layers"]),
                                     unroll=self.cfg.n_layers
                                     if _flags.UNROLL_SCANS else 1)
        logits = self._final(params, x)
        return logits, {"pos": pos + tokens.shape[1], "layers": new_layers}
