"""Decoder-only LM covering dense / GQA / RoPE / MoE / local-global /
hybrid(Mamba) / RWKV / VLM-stub families through one scan-based layer
schedule.

Layers are grouped into a *period* of structurally-distinct positions
(e.g. jamba: 1 attention + 7 mamba; gemma3: 5 local + 1 global; MoE
every-k). Parameters for each period position are stacked over periods and
the model scans over periods — one lowered layer body per position
regardless of depth, keeping dry-run HLO compact at 61-72 layers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import _flags, blocks
from .layers import dense_init, layer_norm, rms_norm


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str                    # attn | mamba | rwkv
    is_moe: bool = False
    window: Optional[int] = None


def build_schedule(cfg: ModelConfig) -> Tuple[List[BlockSpec], int,
                                              List[BlockSpec]]:
    """Returns (period_specs, n_periods, tail_specs)."""
    def pos_spec(i: int) -> BlockSpec:
        if cfg.family == "ssm":
            return BlockSpec("rwkv")
        kind = "attn"
        if cfg.hybrid_period:
            kind = "attn" if i % cfg.hybrid_period == cfg.hybrid_attn_index \
                else "mamba"
        is_moe = bool(cfg.moe) and (i % cfg.moe.moe_every
                                    == cfg.moe.moe_every - 1)
        window = None
        if cfg.local_global_ratio and kind == "attn":
            l, g = cfg.local_global_ratio
            if (i % (l + g)) < l:
                window = cfg.window
        elif cfg.window and kind == "attn":
            window = cfg.window
        return BlockSpec(kind, is_moe, window)

    period = 1
    if cfg.hybrid_period:
        period = np.lcm(period, cfg.hybrid_period)
    if cfg.moe:
        period = np.lcm(period, cfg.moe.moe_every)
    if cfg.local_global_ratio:
        period = np.lcm(period, sum(cfg.local_global_ratio))
    period = int(period)
    n_periods = cfg.n_layers // period
    remainder = cfg.n_layers - n_periods * period
    period_specs = [pos_spec(i) for i in range(period)]
    tail_specs = [pos_spec(n_periods * period + i) for i in range(remainder)]
    return period_specs, n_periods, tail_specs


def _block_init(cfg: ModelConfig, spec: BlockSpec, key) -> Dict:
    p = {}
    k1, k2 = jax.random.split(key)
    if spec.kind == "attn":
        p["attn"] = blocks.attn_init(cfg, k1)
        p["ffn"] = blocks.ffn_init(cfg, k2, spec.is_moe)
    elif spec.kind == "mamba":
        p["mamba"] = blocks.mamba_init(cfg, k1)
        p["ffn"] = blocks.ffn_init(cfg, k2, spec.is_moe)
    elif spec.kind == "rwkv":
        p["rwkv"] = blocks.rwkv_init(cfg, k1)
    return p


def _block_apply(cfg: ModelConfig, spec: BlockSpec, p: Dict, x, *,
                 cache: Optional[Dict] = None, pos=None,
                 training: bool = False):
    """Returns (x, aux, new_cache). ``training`` enables MoE capacity
    dropping; eval-mode forward and decode both run without dropping so
    they agree token-for-token."""
    new_cache = {}
    aux = jnp.zeros((), jnp.float32)
    if spec.kind == "attn":
        c = None
        if cache is not None:
            c = {"k": cache["k"], "v": cache["v"], "pos": pos}
        x, nc = blocks.attn_apply(cfg, p["attn"], x, window=spec.window,
                                  cache=c)
        if nc is not None:
            new_cache = {"k": nc["k"], "v": nc["v"]}
        x, aux = blocks.ffn_apply(cfg, p["ffn"], x, spec.is_moe,
                                  training=training)
    elif spec.kind == "mamba":
        x, nc = blocks.mamba_apply(cfg, p["mamba"], x, cache=cache)
        if nc is not None:
            new_cache = nc
        x, aux = blocks.ffn_apply(cfg, p["ffn"], x, spec.is_moe,
                                  training=training)
    elif spec.kind == "rwkv":
        x, nc = blocks.rwkv_apply(cfg, p["rwkv"], x, cache=cache)
        if nc is not None:
            new_cache = nc
    return x, aux, new_cache


def _block_cache_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                      max_seq: int, dtype) -> Dict:
    if spec.kind == "attn":
        c = blocks.attn_cache_init(cfg, batch, max_seq, dtype)
        return {"k": c["k"], "v": c["v"]}
    if spec.kind == "mamba":
        return blocks.mamba_cache_init(cfg, batch)
    if spec.kind == "rwkv":
        return blocks.rwkv_cache_init(cfg, batch)
    return {}


class TransformerLM:
    """Families: dense | moe | ssm(rwkv) | hybrid | vlm."""

    def __init__(self, cfg: ModelConfig, remat: bool = False):
        self.cfg = cfg
        #: per-layer activation checkpointing: the scan stores only the
        #: layer-boundary activations; attention/FFN internals recompute in
        #: the backward pass (required to fit train_4k at 256 chips).
        self.remat = remat
        #: Megatron-style vocab padding: the embedding/lm_head vocab dim is
        #: padded to a multiple of 256 so it shards over the model axis
        #: (granite's 49155 etc.); padded logits are masked to -inf.
        self.vocab_padded = -(-cfg.vocab // 256) * 256
        self.period_specs, self.n_periods, self.tail_specs = \
            build_schedule(cfg)

    def _apply_block(self, spec, p, x, **kw):
        if self.remat and not kw.get("cache"):
            training = kw.get("training", False)
            fn = jax.checkpoint(
                lambda p_, x_: _block_apply(self.cfg, spec, p_, x_,
                                            training=training)[:2])
            x, aux = fn(p, x)
            return x, aux, {}
        return _block_apply(self.cfg, spec, p, x, **kw)

    # -- parameters ------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        dt = jnp.dtype(cfg.param_dtype)
        params: Dict = {
            "embed": dense_init(keys[0], (self.vocab_padded, cfg.d_model),
                                scale=1.0, dtype=dt),
        }
        body = []
        for pi, spec in enumerate(self.period_specs):
            pk = jax.random.split(jax.random.fold_in(keys[1], pi),
                                  self.n_periods)
            body.append(jax.vmap(
                functools.partial(_block_init, cfg, spec))(pk))
        params["body"] = body
        params["tail"] = [
            _block_init(cfg, spec, jax.random.fold_in(keys[2], i))
            for i, spec in enumerate(self.tail_specs)]
        params["final_scale"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.norm == "layernorm":
            params["final_bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                keys[3], (cfg.d_model, self.vocab_padded), dtype=dt)
        if cfg.n_stub_tokens:
            params["stub_proj"] = dense_init(keys[4],
                                             (cfg.d_model, cfg.d_model),
                                             dtype=dt)
        return params

    # -- forward ----------------------------------------------------------
    def _final_norm(self, params, x):
        if self.cfg.norm == "rmsnorm":
            return rms_norm(x, params["final_scale"])
        return layer_norm(x, params["final_scale"] + 1.0,
                          params["final_bias"])

    def _logits(self, params, x):
        from .layers import psc
        adt = jnp.dtype(self.cfg.activation_dtype)
        head = params["embed"].T if self.cfg.tie_embeddings \
            else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x.astype(adt), head.astype(adt))
        logits = psc(logits, "batch", None, "model")
        if self.cfg.tie_embeddings:  # gemma-style tied-head scaling
            logits = logits * np.float32(1.0 / np.sqrt(self.cfg.d_model)
                                         ).astype(logits.dtype)
        if self.vocab_padded != self.cfg.vocab:
            pad_mask = jnp.arange(self.vocab_padded) >= self.cfg.vocab
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                               logits)
        return logits

    def embed_tokens(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0).astype(
            jnp.dtype(self.cfg.activation_dtype))

    def forward(self, params, batch: Dict, training: bool = False):
        """batch: {'tokens': (B,S) int32, optional 'stub_embeds':
        (B, n_stub, D)} -> (logits, aux_loss). The default is eval mode:
        MoE capacity dropping stays off (capacity = n_tokens), so a full-
        sequence forward matches token-by-token decode bit-for-bit;
        ``loss`` passes training=True to restore the static training
        capacity."""
        cfg = self.cfg
        x = self.embed_tokens(params, batch["tokens"])
        if cfg.n_stub_tokens and "stub_embeds" in batch:
            stub = batch["stub_embeds"].astype(x.dtype)
            stub = jnp.einsum("bsd,de->bse", stub,
                              params["stub_proj"].astype(x.dtype))
            x = jnp.concatenate([stub, x], axis=1)
        aux_total = jnp.zeros((), jnp.float32)

        from .layers import psc

        def period_body(carry, xs):
            x, aux = carry
            for pi, spec in enumerate(self.period_specs):
                x, a, _ = self._apply_block(spec, xs[pi], x,
                                            training=training)
                # sequence parallelism: layer-boundary activations shard
                # their sequence dim over 'model'; GSPMD all-gathers for
                # attention and reduce-scatters after (Megatron-SP).
                x = psc(x, "batch", "seq_model", None)
                aux = aux + a
            return (x, aux), None

        if self.n_periods > 0:
            (x, aux_total), _ = jax.lax.scan(
                period_body, (x, aux_total), tuple(params["body"]),
                length=self.n_periods,
                unroll=self.n_periods if _flags.UNROLL_SCANS else 1)
        for spec, p in zip(self.tail_specs, params["tail"]):
            x, a, _ = self._apply_block(spec, p, x, training=training)
            aux_total = aux_total + a
        x = self._final_norm(params, x)
        logits = self._logits(params, x)
        if cfg.n_stub_tokens and "stub_embeds" in batch:
            logits = logits[:, cfg.n_stub_tokens:]
        return logits, aux_total

    def loss(self, params, batch: Dict):
        logits, aux = self.forward(params, batch, training=True)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        # logsumexp-form CE: avoids a second (B,S,V) log-probability buffer
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
        nll = lse - tgt
        return jnp.mean(nll) + 0.01 * aux

    # -- decode -----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> Dict:
        cfg = self.cfg
        cache: Dict = {"pos": jnp.zeros((), jnp.int32), "body": [], "tail": []}
        for spec in self.period_specs:
            one = _block_cache_init(cfg, spec, batch, max_seq, dtype)
            cache["body"].append(jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.n_periods,) + x.shape), one))
        for spec in self.tail_specs:
            cache["tail"].append(
                _block_cache_init(cfg, spec, batch, max_seq, dtype))
        return cache

    def decode_step(self, params, cache: Dict, tokens):
        """tokens: (B, 1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        pos = cache["pos"]

        def period_body(x, xs):
            p_slice, c_slice = xs
            new_cs = []
            for pi, spec in enumerate(self.period_specs):
                x, _, nc = _block_apply(cfg, spec, p_slice[pi], x,
                                        cache=c_slice[pi], pos=pos)
                new_cs.append(nc)
            return x, tuple(new_cs)

        new_cache: Dict = {"pos": pos + tokens.shape[1], "body": [],
                           "tail": []}
        if self.n_periods > 0:
            x, new_body = jax.lax.scan(
                period_body, x,
                (tuple(params["body"]), tuple(cache["body"])),
                length=self.n_periods,
                unroll=self.n_periods if _flags.UNROLL_SCANS else 1)
            new_cache["body"] = list(new_body)
        for spec, p, c in zip(self.tail_specs, params["tail"],
                              cache["tail"]):
            x, _, nc = _block_apply(cfg, spec, p, x, cache=c, pos=pos)
            new_cache["tail"].append(nc)
        x = self._final_norm(params, x)
        logits = self._logits(params, x)
        return logits, new_cache
