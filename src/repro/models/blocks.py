"""Layer blocks: attention, Mamba SSM, RWKV6 time-mix, FFN/MoE.

Each block is (init_fn, apply_fn) over explicit param dicts, with optional
decode-cache threading. Blocks are scan-stackable: apply works identically
on unstacked params (leading layer dim removed by scan).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .layers import (apply_rope, attention_xla, dense_init, gelu_mlp,
                     layer_norm, moe_block, rms_norm, swiglu)


def _norm(cfg: ModelConfig, x, p, prefix: str):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p[f"{prefix}_scale"])
    return layer_norm(x, p[f"{prefix}_scale"] + 1.0, p[f"{prefix}_bias"])


def _norm_init(cfg: ModelConfig, d: int) -> Dict:
    out = {"_scale": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        out["_bias"] = jnp.zeros((d,), jnp.float32)
    return out


def _with_prefix(d: Dict, prefix: str) -> Dict:
    return {prefix + k: v for k, v in d.items()}


# ---------------------------------------------------------------------------
# Attention block (GQA + RoPE + optional sliding window)
# ---------------------------------------------------------------------------
def attn_init(cfg: ModelConfig, key) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dt),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d),
                         scale=1.0 / np.sqrt(cfg.n_heads * hd * 2
                                             * cfg.n_layers), dtype=dt),
    }
    p.update(_with_prefix(_norm_init(cfg, d), "ln"))
    return p


def attn_apply(cfg: ModelConfig, p: Dict, x, *, window: Optional[int],
               cache: Optional[Dict] = None, positions=None,
               kv_override: Optional[Tuple] = None, causal: bool = True):
    """x: (B, S, D). cache: {'k','v'} (B, Smax, Hkv, Dh) + 'pos' scalar.
    kv_override: cross-attention (encoder memory)."""
    b, s, d = x.shape
    hd = cfg.head_dim
    h = _norm(cfg, x, p, "ln")
    adt = jnp.dtype(cfg.activation_dtype)
    h = h.astype(adt)
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(adt)
                   ).reshape(b, s, cfg.n_heads, hd)
    if kv_override is None:
        k = jnp.einsum("bsd,dh->bsh", h, p["wk"].astype(adt)
                       ).reshape(b, s, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", h, p["wv"].astype(adt)
                       ).reshape(b, s, cfg.n_kv_heads, hd)
    else:
        k, v = kv_override

    if positions is None:
        base = cache["pos"] if cache is not None else 0
        positions = base + jnp.arange(s)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    if kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    q_offset = 0
    if cache is not None and kv_override is None:
        # decode: insert new k/v at position, attend over the whole cache
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        k, v = ck.astype(adt), cv.astype(adt)
        q_offset = pos
        out = attention_xla(q, k, v, causal=True, window=window,
                            q_offset=q_offset)
    elif cfg.attention_impl == "chunked" and s > 1:
        from .layers import attention_chunked
        out = attention_chunked(q, k, v,
                                causal=causal and kv_override is None,
                                window=window)
    else:
        out = attention_xla(q, k, v, causal=causal and kv_override is None,
                            window=window)
    out = out.reshape(b, s, cfg.n_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(adt))
    return x + out.astype(x.dtype), new_cache


def attn_cache_init(cfg: ModelConfig, batch: int, max_seq: int,
                    dtype=jnp.bfloat16) -> Dict:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Mamba selective-SSM block (Jamba's recurrent layer)
# ---------------------------------------------------------------------------
def mamba_init(cfg: ModelConfig, key) -> Dict:
    d = cfg.d_model
    d_in = cfg.expand * d
    n = cfg.d_state
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "w_in": dense_init(ks[0], (d, 2 * d_in), dtype=dt),
        "conv_w": dense_init(ks[1], (cfg.d_conv, d_in), scale=0.5, dtype=dt),
        "w_bcdt": dense_init(ks[2], (d_in, 2 * n + 1), dtype=dt),
        "dt_bias": jnp.full((d_in,), -4.6, dt),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))).astype(dt),
        "D": jnp.ones((d_in,), dt),
        "w_out": dense_init(ks[3], (d_in, d),
                            scale=1.0 / np.sqrt(d_in * 2 * cfg.n_layers),
                            dtype=dt),
    }
    p.update(_with_prefix(_norm_init(cfg, d), "ln"))
    return p


#: mamba chunk length; per-step log-decay is clamped to >= -5 so the
#: exp(cumsum) within a chunk stays in fp32 range (5*16 = 80 < 88).
SSM_CHUNK = 16


def _ssm_scan_ref(u, ldA, dBu, C, state0):
    """Reference selective scan (associative scan over time). Materializes
    (B,S,Din,N) states — smoke-test sizes only; the chunked path below is
    the production formulation."""
    dA = jnp.exp(ldA)                                    # (B,S,Din,N)
    if state0 is not None:
        dBu = dBu.at[:, 0].add(dA[:, 0] * state0)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    _, states = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", states, C)
    return y, states[:, -1]


def _ssm_scan_chunked(u, ldA, dBu, C, state0, chunk: int = SSM_CHUNK):
    """Chunked selective scan (TPU adaptation, DESIGN.md §4): one
    (B,chunk,Din,N) slab lives at a time; chunks propagate the (B,Din,N)
    state through a short scan. exp/cumsum stay in fp32 range thanks to
    the per-step clamp on ldA."""
    Bsz, S, Din = u.shape
    N = ldA.shape[-1]
    nC = S // chunk

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(Bsz, nC, chunk, *x.shape[2:]), 1, 0)

    xs = (to_chunks(ldA), to_chunks(dBu), to_chunks(C))

    def step(state, xs):
        ldA_c, dBu_c, C_c = xs          # (B,chunk,Din,N) x2, (B,chunk,N)
        la = jnp.cumsum(ldA_c, axis=1)
        prefix = jnp.cumsum(jnp.exp(-la) * dBu_c, axis=1)
        states = jnp.exp(la) * (state[:, None] + prefix)
        y = jnp.einsum("bcdn,bcn->bcd", states, C_c)
        return states[:, -1], y

    state_f, ys = jax.lax.scan(step, state0, xs,
                               unroll=nC if _flags.UNROLL_SCANS else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, Din)
    return y, state_f


def mamba_apply(cfg: ModelConfig, p: Dict, x, *, cache: Optional[Dict] = None):
    b, s, d = x.shape
    d_in = cfg.expand * d
    n = cfg.d_state
    adt = jnp.dtype(cfg.activation_dtype)
    h = _norm(cfg, x, p, "ln").astype(adt)
    xz = jnp.einsum("bsd,de->bse", h, p["w_in"].astype(adt))
    u, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time
    conv_w = p["conv_w"].astype(jnp.float32)
    K = cfg.d_conv
    if cache is not None:
        prev = cache["conv"]                          # (B, K-1, Din)
        u_ext = jnp.concatenate([prev.astype(adt), u], axis=1)
        new_conv = u_ext[:, -(K - 1):].astype(cache["conv"].dtype)
    else:
        u_ext = jnp.concatenate([jnp.zeros((b, K - 1, d_in), adt), u], axis=1)
        new_conv = None
    uf = u_ext.astype(jnp.float32)
    conv = sum(uf[:, i:i + s] * conv_w[i] for i in range(K))
    u = jax.nn.silu(conv)

    bcdt = jnp.einsum("bsd,dk->bsk", u.astype(adt), p["w_bcdt"].astype(adt)
                      ).astype(jnp.float32)
    B_, C_, dt_ = bcdt[..., :n], bcdt[..., n:2 * n], bcdt[..., 2 * n:]
    dt_ = jax.nn.softplus(dt_ + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    # per-step log decay, clamped for chunked-scan fp32 stability
    ldA = jnp.clip(dt_[..., None] * A, -5.0, 0.0)        # (B,S,Din,N)
    dBu = dt_[..., None] * B_[:, :, None, :] * u[..., None]
    state0 = cache["ssm"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((b, d_in, n), jnp.float32)
    if s == 1:
        last_state = jnp.exp(ldA[:, 0]) * state0 + dBu[:, 0]
        y = jnp.einsum("bdn,bn->bd", last_state, C_[:, 0])[:, None]
    elif s % SSM_CHUNK == 0:
        y, last_state = _ssm_scan_chunked(u, ldA, dBu, C_, state0)
    else:
        y, last_state = _ssm_scan_ref(u, ldA, dBu, C_, state0)
    y = y + p["D"].astype(jnp.float32) * u
    y = y.astype(adt) * jax.nn.silu(z.astype(jnp.float32)).astype(adt)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"].astype(adt))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv,
                     "ssm": last_state.astype(cache["ssm"].dtype)}
    return x + out.astype(x.dtype), new_cache


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_in = cfg.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, cfg.d_state), dtype),
    }


# ---------------------------------------------------------------------------
# RWKV6 block (Finch): data-dependent decay time-mix + channel mix
# ---------------------------------------------------------------------------
def rwkv_init(cfg: ModelConfig, key) -> Dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "mix_rkvwg": dense_init(ks[0], (5, d), scale=0.1, dtype=dt),
        "wr": dense_init(ks[1], (d, d), dtype=dt),
        "wk": dense_init(ks[2], (d, d), dtype=dt),
        "wv": dense_init(ks[3], (d, d), dtype=dt),
        "wg": dense_init(ks[4], (d, d), dtype=dt),
        "w_decay": dense_init(ks[5], (d,), scale=1.0, dtype=dt),
        "u_bonus": dense_init(ks[6], (H, hd), scale=0.5, dtype=dt),
        "wo": dense_init(ks[7], (d, d),
                         scale=1.0 / np.sqrt(d * 2 * cfg.n_layers), dtype=dt),
        # channel mix
        "cm_wk": dense_init(jax.random.fold_in(key, 10), (d, cfg.d_ff),
                            dtype=dt),
        "cm_wv": dense_init(jax.random.fold_in(key, 11), (cfg.d_ff, d),
                            scale=1.0 / np.sqrt(cfg.d_ff * 2 * cfg.n_layers),
                            dtype=dt),
        "cm_mix": dense_init(jax.random.fold_in(key, 12), (d,), scale=0.1,
                             dtype=dt),
    }
    p.update(_with_prefix(_norm_init(cfg, d), "ln1"))
    p.update(_with_prefix(_norm_init(cfg, d), "ln2"))
    return p


from . import _flags

#: WKV chunk length: bounded so exp(sum log w) stays in fp32 range
#: (|log w| <= 3.5 per step by construction -> 3.5*16 = 56 < 88).
WKV_CHUNK = 16


def _wkv_scan(r, k, v, w, u, state0):
    """Sequential reference for the WKV6 linear recurrence.
    r,k,v: (B,S,H,hd); w decay in (0,1) applies to the key dim;
    u bonus: (H,hd). state: (B,H,hd_k,hd_v).
    out_t = r_t . (S_{t-1} + u*k_t v_t^T); S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(state, xs):
        rt, kt, vt, wt = xs          # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]        # (B,H,hd,hd)
        out = jnp.einsum("bhkv,bhk->bhv", state + u[..., :, None] * kv, rt)
        new_state = wt[..., :, None] * state + kv
        return new_state, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1), state  # (B,S,H,hd)


def _wkv_chunked(r, k, v, w, u, state0, chunk: int = WKV_CHUNK):
    """Chunkwise WKV6 (GLA-style): intra-chunk terms become causal matmuls
    on the MXU; only the O(S/chunk) inter-chunk state propagation scans.
    This is the TPU adaptation of the recurrence (DESIGN.md §4) and the
    formulation the Pallas kernel implements.

    With A_t = prod_{s<=t} w_s (per key channel, within a chunk):
      out_t = (r_t*A_{t-1}) . S_chunk0
              + sum_{j<t} [(r_t*A_{t-1}/A_j) . k_j] v_j
              + (r_t . (u*k_t)) v_t
      S_next = diag(A_last) S_chunk0 + sum_j (A_last/A_j) k_j v_j^T
    """
    B, S, H, hd = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    N = S // C

    def chunkify(x):
        return x.reshape(B, N, C, H, hd)

    rc, kc, vc, wc = map(chunkify, (r, k, v, w))
    lw = jnp.log(jnp.maximum(wc, 1e-8))
    la = jnp.cumsum(lw, axis=2)                    # inclusive log-decay
    a_incl = jnp.exp(la)                           # A_j
    a_prev = jnp.exp(la - lw)                      # A_{t-1}
    a_last = jnp.exp(la[:, :, -1])                 # (B,N,H,hd) chunk decay
    r_t = rc * a_prev
    k_t = kc * jnp.exp(-la)
    k_rev = kc * jnp.exp(la[:, :, -1:, :, :] - la)  # (A_last/A_j) k_j

    # intra-chunk: strictly-causal scores + diagonal bonus term
    scores = jnp.einsum("bnthd,bnjhd->bnhtj", r_t, k_t)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    out_intra = jnp.einsum("bnhtj,bnjhd->bnthd", scores, vc)
    diag = jnp.einsum("bnthd,hd,bnthd->bnth", rc, u, kc)
    out_intra = out_intra + diag[..., None] * vc

    # inter-chunk state propagation
    t_mat = jnp.einsum("bnjhd,bnjhe->bnhde", k_rev, vc)  # (B,N,H,hd,hd)

    def step(state, xs):
        d_n, t_n = xs                              # (B,H,hd), (B,H,hd,hd)
        new_state = d_n[..., :, None] * state + t_n
        return new_state, state                    # emit the *incoming* state

    d_xs = jnp.moveaxis(a_last, 1, 0)
    t_xs = jnp.moveaxis(t_mat, 1, 0)
    state_f, init_states = jax.lax.scan(step, state0, (d_xs, t_xs),
                                        unroll=N if _flags.UNROLL_SCANS else 1)
    init_states = jnp.moveaxis(init_states, 0, 1)  # (B,N,H,hd,hd)
    out_inter = jnp.einsum("bnthd,bnhde->bnthe", r_t, init_states)
    out = (out_intra + out_inter).reshape(B, S, H, hd)
    return out, state_f


def rwkv_apply(cfg: ModelConfig, p: Dict, x, *, cache: Optional[Dict] = None):
    b, s, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    adt = jnp.dtype(cfg.activation_dtype)

    # --- time mix ---
    h = _norm(cfg, x, p, "ln1").astype(jnp.float32)
    prev_tm = cache["shift1"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((b, 1, d), jnp.float32)
    shifted = jnp.concatenate([prev_tm, h[:, :-1]], axis=1)
    mix = jax.nn.sigmoid(p["mix_rkvwg"].astype(jnp.float32))  # (5, d)
    def lerp(i):
        return h + (shifted - h) * mix[i]
    r = jnp.einsum("bsd,de->bse", lerp(0).astype(adt), p["wr"].astype(adt))
    k = jnp.einsum("bsd,de->bse", lerp(1).astype(adt), p["wk"].astype(adt))
    v = jnp.einsum("bsd,de->bse", lerp(2).astype(adt), p["wv"].astype(adt))
    g = jnp.einsum("bsd,de->bse", lerp(4).astype(adt), p["wg"].astype(adt))
    # data-dependent decay (Finch): w = exp(-softplus(base + lora(x)))
    wdec = jax.nn.sigmoid(lerp(3) * p["w_decay"].astype(jnp.float32))
    w = jnp.exp(-0.5 - 3.0 * wdec)  # in (0,1), data-dependent

    rs = r.reshape(b, s, H, hd).astype(jnp.float32)
    ks_ = k.reshape(b, s, H, hd).astype(jnp.float32)
    vs = v.reshape(b, s, H, hd).astype(jnp.float32)
    ws = w.reshape(b, s, H, hd)
    state0 = cache["wkv"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((b, H, hd, hd), jnp.float32)
    ub = p["u_bonus"].astype(jnp.float32)
    if s == 1:
        out, new_state = _wkv_scan(rs, ks_, vs, ws, ub, state0)
    elif s % WKV_CHUNK == 0:
        out, new_state = _wkv_chunked(rs, ks_, vs, ws, ub, state0)
    else:
        out, new_state = _wkv_scan(rs, ks_, vs, ws, ub, state0)
    out = out.reshape(b, s, d)
    out = out * jax.nn.silu(g.astype(jnp.float32))
    x = x + jnp.einsum("bsd,de->bse", out.astype(adt),
                       p["wo"].astype(adt)).astype(x.dtype)

    # --- channel mix ---
    h2 = _norm(cfg, x, p, "ln2").astype(jnp.float32)
    prev_cm = cache["shift2"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((b, 1, d), jnp.float32)
    shifted2 = jnp.concatenate([prev_cm, h2[:, :-1]], axis=1)
    mix2 = jax.nn.sigmoid(p["cm_mix"].astype(jnp.float32))
    hk = h2 + (shifted2 - h2) * mix2
    kk = jnp.einsum("bsd,df->bsf", hk.astype(adt), p["cm_wk"].astype(adt))
    kk = jnp.square(jnp.maximum(kk.astype(jnp.float32), 0.0)).astype(adt)
    out2 = jnp.einsum("bsf,fd->bsd", kk, p["cm_wv"].astype(adt))
    x = x + out2.astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {
            "shift1": h[:, -1:].astype(cache["shift1"].dtype),
            "shift2": h2[:, -1:].astype(cache["shift2"].dtype),
            "wkv": new_state.astype(cache["wkv"].dtype),
        }
    return x, new_cache


def rwkv_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    return {
        "shift1": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "shift2": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                         dtype),
    }


# ---------------------------------------------------------------------------
# FFN / MoE sublayer
# ---------------------------------------------------------------------------
def ffn_init(cfg: ModelConfig, key, is_moe: bool) -> Dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    p: Dict = {}
    if is_moe:
        m = cfg.moe
        ks = jax.random.split(key, 5)
        e, f = m.n_experts, m.d_ff_expert
        p["router"] = dense_init(ks[0], (d, e), dtype=dt)
        p["moe_gate"] = dense_init(ks[1], (e, d, f), dtype=dt)
        p["moe_up"] = dense_init(ks[2], (e, d, f), dtype=dt)
        p["moe_down"] = dense_init(
            ks[3], (e, f, d), scale=1.0 / np.sqrt(f * 2 * cfg.n_layers),
            dtype=dt)
        if m.shared_expert:
            ks2 = jax.random.split(ks[4], 3)
            p["sh_gate"] = dense_init(ks2[0], (d, f), dtype=dt)
            p["sh_up"] = dense_init(ks2[1], (d, f), dtype=dt)
            p["sh_down"] = dense_init(
                ks2[2], (f, d), scale=1.0 / np.sqrt(f * 2 * cfg.n_layers),
                dtype=dt)
    else:
        f = cfg.d_ff
        ks = jax.random.split(key, 3)
        if cfg.act == "swiglu":
            p["w_gate"] = dense_init(ks[0], (d, f), dtype=dt)
            p["w_up"] = dense_init(ks[1], (d, f), dtype=dt)
            p["w_down"] = dense_init(
                ks[2], (f, d), scale=1.0 / np.sqrt(f * 2 * cfg.n_layers),
                dtype=dt)
        else:
            p["w_in"] = dense_init(ks[0], (d, f), dtype=dt)
            p["b_in"] = jnp.zeros((f,), dt)
            p["w_out"] = dense_init(
                ks[1], (f, d), scale=1.0 / np.sqrt(f * 2 * cfg.n_layers),
                dtype=dt)
            p["b_out"] = jnp.zeros((d,), dt)
    p.update(_with_prefix(_norm_init(cfg, d), "ln"))
    return p


def ffn_apply(cfg: ModelConfig, p: Dict, x, is_moe: bool,
              training: bool = False):
    # default matches forward()'s eval mode: an MoE call site that omits
    # the flag must not silently reintroduce capacity dropping (and the
    # decode-vs-forward divergence that comes with it)
    adt = jnp.dtype(cfg.activation_dtype)
    h = _norm(cfg, x, p, "ln").astype(adt)
    aux = jnp.zeros((), jnp.float32)
    if is_moe:
        m = cfg.moe
        shared = None
        if m.shared_expert:
            shared = {"w_gate": p["sh_gate"].astype(adt),
                      "w_up": p["sh_up"].astype(adt),
                      "w_down": p["sh_down"].astype(adt)}
        out, aux = moe_block(
            h, p["router"], p["moe_gate"].astype(adt),
            p["moe_up"].astype(adt), p["moe_down"].astype(adt),
            top_k=m.top_k, capacity_factor=m.capacity_factor, shared=shared,
            dispatch=cfg.moe_dispatch, drop_tokens=training)
    elif cfg.act == "swiglu":
        out = swiglu(h, p["w_gate"].astype(adt), p["w_up"].astype(adt),
                     p["w_down"].astype(adt))
    else:
        out = gelu_mlp(h, p["w_in"].astype(adt), p["b_in"].astype(adt),
                       p["w_out"].astype(adt), p["b_out"].astype(adt))
    return x + out.astype(x.dtype), aux
