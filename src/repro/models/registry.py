"""Model registry: config -> model instance + input_specs builder."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeSpec
from .encdec import EncDecLM
from .transformer import TransformerLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return TransformerLM(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: int = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    else:
        s_tok = S
        if cfg.n_stub_tokens and cfg.family in ("vlm",):
            s_tok = S - cfg.n_stub_tokens
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_tok), jnp.int32)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["stub_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_stub_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_stub_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def example_batch(cfg: ModelConfig, shape_name: str, batch: int, seq: int,
                  seed: int = 0) -> Dict[str, np.ndarray]:
    """Small concrete batch for smoke tests."""
    rng = np.random.default_rng(seed)
    batch_d: Dict[str, np.ndarray] = {}
    batch_d["tokens"] = rng.integers(0, cfg.vocab, (batch, seq),
                                     dtype=np.int32)
    if cfg.family == "vlm":
        batch_d["stub_embeds"] = rng.standard_normal(
            (batch, cfg.n_stub_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch_d["frames"] = rng.standard_normal(
            (batch, cfg.n_stub_tokens, cfg.d_model)).astype(np.float32)
    return batch_d
