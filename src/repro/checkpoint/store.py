"""Checkpointing with elastic resharding.

Leaves are saved as individual ``.npy`` files under a step directory with
a JSON manifest of the tree structure. Restore rebuilds the pytree and
``jax.device_put``s each leaf with the *target* sharding — which may belong
to a different mesh than the one that saved it (elastic scaling: restart on
more or fewer chips re-shards transparently).

Multi-host layout (``save_sharded``): leaves that are sharded jax Arrays
are written as one file **per addressable shard** (`leaf_00003.s001.npy`),
the way a real pod writes per-host shard files, with the shard's global
index slices and the saving mesh's signature recorded in the manifest.
``restore`` reassembles the global array from the shard files before
resharding onto the target mesh, so a restore onto a smaller mesh is just
a different ``shardings`` argument. A missing shard file (the dead host's
piece) raises :class:`CheckpointError` naming it, so callers can fall back
to an older full checkpoint or recompute.

Atomicity: writes go to ``<dir>.tmp``; commit renames the previous step
directory aside, moves the tmp dir in, then deletes the old one — at every
instant there is a complete checkpoint on disk (the old one until the
rename, the new one after). A bare ``rmtree(live); rename(tmp)`` sequence
would leave *no* valid checkpoint if the process died between the calls.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """Typed checkpoint failure: manifest/tree mismatch or missing shard."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _commit(d: Path, tmp: Path) -> None:
    """Atomically replace ``d`` with ``tmp``: rename the live dir aside,
    move tmp in, then delete — never a window with no valid checkpoint."""
    old = Path(str(d) + ".old")
    if old.exists():
        shutil.rmtree(old)
    if d.exists():
        os.rename(d, old)
    os.rename(tmp, d)
    if old.exists():
        shutil.rmtree(old)


def save(ckpt_dir: str, step: int, state) -> str:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = Path(str(d) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    _commit(d, tmp)
    return str(d)


def _shard_entries(leaf):
    """Unique (index, data) pairs for a sharded jax Array, deduplicated by
    global index so replicated axes write one copy, like one host would."""
    shards = getattr(leaf, "addressable_shards", None)
    if not shards:
        return None
    seen = {}
    for s in shards:
        key = tuple((sl.start, sl.stop) for sl in s.index)
        if key not in seen:
            seen[key] = (s.index, np.asarray(s.data))
    return list(seen.values())


def save_sharded(ckpt_dir: str, step: int, state, mesh_sig=None) -> str:
    """Per-host shard-file checkpoint: each addressable shard of each leaf
    goes to its own file; the manifest records the saving mesh signature
    and each shard's global index, so restore can reassemble (and a shrink
    restore is just new target shardings)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = Path(str(d) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}, "sharded": True,
                "mesh_signature": repr(mesh_sig) if mesh_sig else None}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        entries = _shard_entries(leaf)
        if entries is None or len(entries) == 1:
            # replicated (one unique shard index covers the whole array)
            # or host-local leaf: one full file
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
            continue
        files = []
        for j, (index, data) in enumerate(entries):
            fname = f"leaf_{i:05d}.s{j:03d}.npy"
            np.save(tmp / fname, data)
            files.append({"file": fname,
                          "index": [[sl.start, sl.stop] for sl in index]})
        manifest["leaves"][key] = {
            "shards": files, "dtype": str(entries[0][1].dtype),
            "shape": list(shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    _commit(d, tmp)
    return str(d)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if not (p.name.endswith(".tmp") or p.name.endswith(".old"))]
    return max(steps) if steps else None


def _load_leaf(d: Path, key: str, meta: Dict) -> np.ndarray:
    if "shards" not in meta:
        f = d / meta["file"]
        if not f.exists():
            raise CheckpointError(
                f"checkpoint leaf {key!r}: file {meta['file']} missing "
                f"from {d}")
        return np.load(f)
    out = np.zeros(tuple(meta["shape"]), dtype=np.dtype(meta["dtype"]))
    for sh in meta["shards"]:
        f = d / sh["file"]
        if not f.exists():
            raise CheckpointError(
                f"checkpoint leaf {key!r}: shard file {sh['file']} "
                f"(global index {sh['index']}) missing from {d} — the "
                f"host that wrote it is gone; restore an older full "
                f"checkpoint or recompute")
        idx = tuple(slice(a, b) for a, b in sh["index"])
        out[idx] = np.load(f)
    return out


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Rebuild ``like``-structured state; reshard onto ``shardings``
    (a matching pytree of NamedSharding, possibly for a different mesh).

    Raises :class:`CheckpointError` naming the leaf when the manifest and
    the ``like`` tree disagree (optimizer or architecture changed between
    save and restore) or when a shard file is missing.
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key in flat_like:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise CheckpointError(
                f"checkpoint step {step} has no leaf {key!r}: the saved "
                f"manifest ({len(manifest['leaves'])} leaves) does not "
                f"match the restore target tree — optimizer or model "
                f"architecture changed between save and restore")
        arr = _load_leaf(d, key, meta)
        sh = flat_sh.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else arr
    # unflatten back into like's structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    ordered = []
    for path, _ in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


def manifest_for(ckpt_dir: str, step: int) -> Dict:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())
