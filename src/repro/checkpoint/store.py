"""Checkpointing with elastic resharding.

Leaves are saved as individual ``.npy`` files under a step directory with
a JSON manifest of the tree structure. Restore rebuilds the pytree and
``jax.device_put``s each leaf with the *target* sharding — which may belong
to a different mesh than the one that saved it (elastic scaling: restart on
more or fewer chips re-shards transparently; on real multi-host pods the
same layout maps onto per-host array-shard files).

Atomicity: writes go to ``<dir>.tmp`` then rename; a crash mid-save leaves
the previous checkpoint intact (checkpoint/restart fault tolerance).
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, state) -> str:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = Path(str(d) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if d.exists():
        shutil.rmtree(d)
    os.rename(tmp, d)
    return str(d)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Rebuild ``like``-structured state; reshard onto ``shardings``
    (a matching pytree of NamedSharding, possibly for a different mesh)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key in flat_like:
        meta = manifest["leaves"][key]
        arr = np.load(d / meta["file"])
        sh = flat_sh.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else arr
    # unflatten back into like's structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    ordered = []
    for path, _ in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)
