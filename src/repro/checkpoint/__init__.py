from .store import (CheckpointError, latest_step, manifest_for, restore,
                    save, save_sharded)

__all__ = ["CheckpointError", "latest_step", "manifest_for", "restore",
           "save", "save_sharded"]
