from .optimizers import (Optimizer, adafactor, adamw, clip_by_global_norm,
                         get_optimizer, global_norm, warmup_cosine)

__all__ = ["Optimizer", "adafactor", "adamw", "clip_by_global_norm",
           "get_optimizer", "global_norm", "warmup_cosine"]
