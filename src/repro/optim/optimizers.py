"""Optimizers: AdamW and Adafactor (low-memory, for the XXL MoE archs),
with warmup-cosine schedule and global-norm clipping.

Optimizer state shardings mirror parameter shardings (ZeRO-style: the 2D
(data x model) param sharding automatically shards the moments), which is
what makes 1T-parameter training states fit per-chip HBM at 512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(np.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return schedule


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


@dataclasses.dataclass
class Optimizer:
    init: Callable            # params -> opt_state
    update: Callable          # (grads, opt_state, params, step) ->
    #                           (new_params, new_opt_state)
    name: str = "opt"


def adamw(schedule: Callable, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, opt, params, step):
        lr = schedule(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        m_leaves = treedef.flatten_up_to(opt["m"])
        v_leaves = treedef.flatten_up_to(opt["v"])
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(g_leaves, m_leaves, v_leaves, p_leaves)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update, "adamw")


def adafactor(schedule: Callable, eps=1e-30, decay=0.8,
              clip_threshold=1.0, weight_decay=0.0) -> Optimizer:
    """Factored second moments: O(n+m) state for an (n, m) matrix — the
    memory trick that lets the 1T-param configs train on 512 chips."""

    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return jax.tree.map(leaf, params)

    def update(grads, opt, params, step):
        lr = schedule(step)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, o, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if g.ndim >= 2:
                vr = beta * o["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * o["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps))
                u = g / (denom + eps)
                new_o = {"vr": vr, "vc": vc}
            else:
                v = beta * o["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps)
                new_o = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_o

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        o_leaves = treedef.flatten_up_to(opt)
        out = [upd(g, o, p) for g, o, p in
               zip(g_leaves, o_leaves, p_leaves)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_opt = treedef.unflatten([o[1] for o in out])
        return new_params, new_opt

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr: float = 3e-4, warmup: int = 100,
                  total: int = 10000) -> Optimizer:
    sched = warmup_cosine(lr, warmup, total)
    if name == "adamw":
        return adamw(sched)
    if name == "adafactor":
        return adafactor(sched)
    raise ValueError(name)
