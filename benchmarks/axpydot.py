"""Paper Table 1: AXPYDOT naive vs streaming transformations.

Reports (a) off-chip volume, analytic from memlets at the paper's size
(209,715,200 elements = 800 MiB), (b) wall-clock on CPU at a reduced size
for naive / streamed(jnp) / fused Pallas-interpret variants, (c) PE/module
counts (paper: 1 module naive -> 5 modules streamed), (d) the native grid
path, unfused (axpy kernel + dot kernel, z round-trips through HBM) vs
MapFusion (ONE grid kernel, z held in-kernel).
"""
from __future__ import annotations

import time

import numpy as np

import repro.kernels  # noqa: F401
from repro.frontends import blas
from repro.frontends.api import Program
from repro.pipeline import (DeviceOffloadPass, ExpandLibraryNodesPass,
                            GridConversionPass, MapFusionPass, MapTilingPass,
                            PassManager, SetExpansionPreferencePass,
                            StreamingCompositionPass, StreamingMemoryPass,
                            lower)
from repro.transforms import (DeviceOffload, StreamingComposition,
                              StreamingMemory)

PAPER_N = 209_715_200
BENCH_N = 2_000_000
GRID_N = 262_144          # grid-path comparison (interpret-mode kernels)


def build(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


def build_two_producer(n):
    """Fused-DAG variant: BOTH dot operands are produced —
    ``result = (a*x + y) . (b*u + v)``. The dot scope is fed by two
    independent producer exits; MapFusion folds both axpys in, so the
    whole DAG is ONE grid kernel with two in-kernel intermediates."""
    p = Program("axpydot2")
    a = p.scalar_input("a", "float32")
    b = p.scalar_input("b", "float32")
    x, y, u, v = (p.input(nm, (n,)) for nm in ("x", "y", "u", "v"))
    p.output("result", blas.dot(blas.axpy(a, x, y), blas.axpy(b, u, v)))
    return p.finalize()


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    np.asarray(out["result"]).block_until_ready() if hasattr(
        np.asarray(out["result"]), "block_until_ready") else None
    return (time.perf_counter() - t0) / reps


def run(report, small: bool = False):
    bench_n = 65_536 if small else BENCH_N
    rng = np.random.default_rng(0)
    a = np.float32(0.7)
    x, y, w = (rng.standard_normal(bench_n).astype(np.float32)
               for _ in range(3))
    exp = np.dot((a * x + y).astype(np.float32), w)

    # volumes at the paper's N (analytic, exact)
    naive = build(PAPER_N)
    naive.apply(DeviceOffload)
    v_naive = naive.off_chip_volume()
    streamed = build(PAPER_N)
    streamed.apply(DeviceOffload)
    streamed.apply(StreamingComposition)
    streamed.apply(StreamingMemory)
    v_stream = streamed.off_chip_volume()
    pes = len([s for s in streamed.states if s.label == "main"][0]
              .processing_elements())

    # runtimes at reduced N, through the staged pipeline
    c1 = lower(build(bench_n)).optimize([DeviceOffloadPass()]).compile("jnp")
    t_naive = _time(c1, a=a, x=x, y=y, w=w)
    out = c1(a=a, x=x, y=y, w=w)
    assert abs(float(np.asarray(out["result"]).ravel()[0]) - exp) < \
        1e-3 * abs(exp)

    c2 = lower(build(bench_n)).optimize(
        [DeviceOffloadPass(), StreamingCompositionPass(),
         StreamingMemoryPass()]).compile("jnp")
    t_stream = _time(c2, a=a, x=x, y=y, w=w)

    c3 = lower(build(bench_n)).optimize(
        [DeviceOffloadPass(), StreamingCompositionPass()]).compile("pallas")
    t_fused = _time(c3, a=a, x=x, y=y, w=w)

    report("axpydot_naive_volume_GiB", v_naive / 2**30,
           f"paper table1; n={PAPER_N}")
    report("axpydot_stream_volume_GiB", v_stream / 2**30,
           f"volume ratio {v_naive/v_stream:.3f} (z round-trip removed)")
    report("axpydot_stream_PEs", pes, "paper: 5 modules (we count writer+dot)")
    report("axpydot_naive_ms", t_naive * 1e3, f"n={bench_n}, CPU jnp")
    report("axpydot_stream_ms", t_stream * 1e3,
           f"speedup {t_naive/t_stream:.2f}x (paper: 2.6x on U250)")
    report("axpydot_fused_pallas_ms", t_fused * 1e3,
           f"fused regions {c3.report['fused_regions']}", backend="pallas")

    # (d) native grid path: unfused kernel pair vs MapFusion single kernel
    gn = 65_536 if small else GRID_N
    gx, gy, gw = (rng.standard_normal(gn).astype(np.float32)
                  for _ in range(3))
    g_exp = np.dot((a * gx + gy).astype(np.float32), gw)

    def grid_pipeline(fused: bool, tiled: bool = True) -> PassManager:
        passes = [SetExpansionPreferencePass(("accumulate", "generic")),
                  ExpandLibraryNodesPass()]
        if fused:
            passes.append(MapFusionPass())
        if tiled:
            passes.append(MapTilingPass(tile_size=128))
        passes.append(GridConversionPass())
        return PassManager(passes, name=f"grid_f{int(fused)}_t{int(tiled)}")

    cu = lower(build(gn)).compile("pallas", pipeline=grid_pipeline(False))
    t_grid_unfused = _time(cu, a=a, x=gx, y=gy, w=gw, reps=3)
    assert len(cu.report["grid_kernels"]) == 2
    cf = lower(build(gn)).compile("pallas", pipeline=grid_pipeline(True))
    t_grid_fused = _time(cf, a=a, x=gx, y=gy, w=gw, reps=3)
    assert len(cf.report["grid_kernels"]) == 1
    # 1-element-block variant at a reduced size: an untiled interpret-mode
    # grid steps once per ELEMENT, so the full gn would take minutes
    un = max(1024, gn // 32)
    ux, uy, uw = gx[:un], gy[:un], gw[:un]
    cnt = lower(build(un)).compile("pallas",
                                   pipeline=grid_pipeline(True, tiled=False))
    t_grid_untiled = _time(cnt, a=a, x=ux, y=uy, w=uw, reps=1)
    ct = lower(build(un)).compile("pallas", pipeline=grid_pipeline(True))
    t_tiled_small = _time(ct, a=a, x=ux, y=uy, w=uw, reps=1)
    for c in (cu, cf):
        got = float(np.asarray(c(a=a, x=gx, y=gy, w=gw)["result"]).ravel()[0])
        assert abs(got - g_exp) < 1e-3 * abs(g_exp)
    u_exp = np.dot((a * ux + uy).astype(np.float32), uw)
    for c in (cnt, ct):
        got = float(np.asarray(c(a=a, x=ux, y=uy, w=uw)["result"]).ravel()[0])
        assert abs(got - u_exp) < 1e-3 * abs(u_exp)

    report("axpydot_grid_unfused_ms", t_grid_unfused * 1e3,
           f"n={gn}; kernels={cu.report['grid_kernels']}", backend="pallas")
    report("axpydot_grid_fused_ms", t_grid_fused * 1e3,
           f"n={gn}; 1 kernel, z in-kernel; speedup "
           f"{t_grid_unfused/t_grid_fused:.2f}x vs unfused grid",
           backend="pallas")
    report("axpydot_grid_untiled_ms", t_grid_untiled * 1e3,
           f"n={un}; fused but 1-element blocks; tiled speedup "
           f"{t_grid_untiled/t_tiled_small:.2f}x at same n",
           backend="pallas")
    assert t_tiled_small < t_grid_untiled, \
        "tiled grid variant must beat the 1-element-block grid variant"

    # two-producer DAG: dot over TWO generated operands fuses to ONE kernel
    gb = np.float32(-0.3)
    gu, gv = (rng.standard_normal(gn).astype(np.float32) for _ in range(2))
    d_exp = np.dot((a * gx + gy).astype(np.float32),
                   (gb * gu + gv).astype(np.float32))
    c2u = lower(build_two_producer(gn)).compile(
        "pallas", pipeline=grid_pipeline(False))
    t_dag_unfused = _time(c2u, a=a, b=gb, x=gx, y=gy, u=gu, v=gv, reps=3)
    assert len(c2u.report["grid_kernels"]) == 3
    c2f = lower(build_two_producer(gn)).compile(
        "pallas", pipeline=grid_pipeline(True))
    t_dag_fused = _time(c2f, a=a, b=gb, x=gx, y=gy, u=gu, v=gv, reps=3)
    assert len(c2f.report["grid_kernels"]) == 1, \
        f"two-producer DAG must fuse to ONE kernel, got " \
        f"{c2f.report['grid_kernels']}"
    for c in (c2u, c2f):
        got = float(np.asarray(
            c(a=a, b=gb, x=gx, y=gy, u=gu, v=gv)["result"]).ravel()[0])
        assert abs(got - d_exp) < 1e-3 * abs(d_exp)
    report("axpydot_dag_unfused_ms", t_dag_unfused * 1e3,
           f"n={gn}; kernels={c2u.report['grid_kernels']}", backend="pallas",
           grid_kernels=len(c2u.report["grid_kernels"]))
    report("axpydot_dag_fused_ms", t_dag_fused * 1e3,
           f"n={gn}; two-producer dot as ONE kernel, both axpys in-kernel; "
           f"speedup {t_dag_unfused/t_dag_fused:.2f}x vs unfused",
           backend="pallas", grid_kernels=len(c2f.report["grid_kernels"]))
