"""Paper Table 2: GEMVER composition ladder.

    B = A + u1 v1^T + u2 v2^T ; x = beta*B^T y + z ; w = alpha*B x

Variants: naive / streaming composition / manual composition (the paper's
§4.2 replication of the rank-1-update result so pipeline fusion applies
once more). Volumes analytic at the paper's N=16,384 (GiB); runtime at a
reduced N on CPU. The native grid path additionally compares the unfused
kernel ladder (2x ger + 2x gemv grid kernels, B1 round-tripping through
HBM) against MapFusion (the two rank-1 updates as ONE grid kernel with
B1 held in-kernel).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Memlet
from repro.frontends import blas
from repro.frontends.api import Program
from repro.pipeline import (ExpandLibraryNodesPass, GridConversionPass,
                            MapFusionPass, MapTilingPass, PassManager,
                            SetExpansionPreferencePass, lower)
from repro.transforms import DeviceOffload, StreamingComposition

PAPER_N = 16_384
BENCH_N = 1024
GRID_N = 128              # grid-path comparison (interpret-mode kernels)


def build(n, manual_replication=False, replica_in_hbm=True):
    p = Program("gemver")
    A = p.input("A", (n, n))
    u1, v1 = p.input("u1", (n,)), p.input("v1", (n,))
    u2, v2 = p.input("u2", (n,)), p.input("v2", (n,))
    yv, zv = p.input("y", (n,)), p.input("z", (n,))
    B1 = blas.ger(A, u1, v1)
    B2 = blas.ger(B1, u2, v2)
    # x = beta * B^T y + z
    x = blas.gemv(B2, yv, y0=zv, trans=True, alpha=0.9, beta=1.0)
    if manual_replication:
        # fork the second GER's output: one replica streams into the
        # transposed GEMV; the other feeds the row-major GEMV
        # (paper §4.2 'manually replicate C following expansion').
        # replica_in_hbm=True keeps that replica off-chip exactly as the
        # paper does (3 GiB); False lets StreamingComposition stream BOTH
        # replicas (beyond-paper: 1 GiB kernel volume).
        st = p.state
        rep = p.temp(B2.shape, B2.dtype, name="B2_rep")
        producer_edge = st.in_edges(B2.node)[0]
        rep_node = st.add_access(rep.name)
        st.add_edge(producer_edge.src, producer_edge.src_conn, rep_node,
                    None, Memlet.simple(rep.name))
        from repro.frontends.api import TensorHandle
        B2b = TensorHandle(p, rep.name, B2.shape, B2.dtype, node=rep_node)
        w = blas.gemv(B2b, x, alpha=1.1)
        if replica_in_hbm:
            # pin the replica off-chip: composition must not stream it
            p.sdfg.metadata["pin_hbm"] = {rep.name}
    else:
        w = blas.gemv(B2, x, alpha=1.1)
    p.output("x_out", x)
    p.output("w_out", w)
    return p.finalize()


def build_chain(n):
    """The fused-DAG ladder rung: B = A + u1 v1^T + u2 v2^T ; w = alpha*B x.
    With the elementwise-exact ``accumulate`` gemv expansion the whole
    ger->ger->gemv chain is one iteration space and MapFusion collapses it
    into ONE grid kernel (B1 and B2 never leave the kernel)."""
    p = Program("gemver_chain")
    A = p.input("A", (n, n))
    u1, v1 = p.input("u1", (n,)), p.input("v1", (n,))
    u2, v2 = p.input("u2", (n,)), p.input("v2", (n,))
    xv = p.input("xw", (n,))
    B1 = blas.ger(A, u1, v1)
    B2 = blas.ger(B1, u2, v2)
    p.output("w_out", blas.gemv(B2, xv, alpha=1.1))
    return p.finalize()


def reference(n, d):
    B = d["A"] + np.outer(d["u1"], d["v1"]) + np.outer(d["u2"], d["v2"])
    x = 0.9 * B.T @ d["y"] + d["z"]
    w = 1.1 * B @ x
    return x, w


def _variants(n):
    out = {}
    s = build(n)
    s.apply(DeviceOffload)
    out["naive"] = s
    s2 = build(n)
    s2.apply(DeviceOffload)
    s2.apply(StreamingComposition)
    out["streaming"] = s2
    s3 = build(n, manual_replication=True, replica_in_hbm=True)
    s3.apply(DeviceOffload)
    s3.apply(StreamingComposition)
    out["manual"] = s3
    # beyond-paper: both replicas stream (kernel volume -> 1 matrix pass)
    s4 = build(n, manual_replication=True, replica_in_hbm=False)
    s4.apply(DeviceOffload)
    s4.apply(StreamingComposition)
    out["both_streamed"] = s4
    return out


def _kernel_volume(sdfg):
    """Kernel-state volume only (the paper's Table-2 column excludes the
    host<->device staging copies)."""
    main = [st for st in sdfg.states if st.label == "main"][0]
    return main.off_chip_volume()


def run(report, small: bool = False):
    rng = np.random.default_rng(0)
    n = 256 if small else BENCH_N
    d = {k: rng.standard_normal((n, n) if k == "A" else n
                                ).astype(np.float32)
         for k in ("A", "u1", "v1", "u2", "v2", "y", "z")}
    x_ref, w_ref = reference(n, d)

    vols = {name: _kernel_volume(s) for name, s in
            _variants(PAPER_N).items()}
    times = {}
    for name, s in _variants(n).items():
        c = lower(s).compile("jnp")
        c(**d)  # compile
        t0 = time.perf_counter()
        out = c(**d)
        times[name] = time.perf_counter() - t0
        np.testing.assert_allclose(np.asarray(out["x_out"]), x_ref,
                                   rtol=5e-2, atol=5e-1)
        np.testing.assert_allclose(np.asarray(out["w_out"]), w_ref,
                                   rtol=5e-2, atol=5e-1)

    paper = {"naive": "6.0", "streaming": "4.0", "manual": "3.0",
             "both_streamed": "(beyond-paper)"}
    for name in ("naive", "streaming", "manual", "both_streamed"):
        report(f"gemver_{name}_volume_GiB", vols[name] / 2**30,
               f"paper table2 {paper[name]} GiB; "
               f"ratio {vols['naive']/vols[name]:.2f}x")
        report(f"gemver_{name}_ms", times[name] * 1e3, f"n={n} CPU")

    # native grid path: unfused kernel ladder vs MapFusion'd rank-1 pair
    gn = 64 if small else GRID_N
    gd = {k: rng.standard_normal((gn, gn) if k == "A" else gn
                                 ).astype(np.float32)
          for k in ("A", "u1", "v1", "u2", "v2", "y", "z")}
    gx_ref, gw_ref = reference(gn, gd)

    grid_times, kernels, blocks = {}, {}, {}
    for name, fused, tiled in (("unfused", False, True),
                               ("fused", True, True),
                               ("untiled", True, False)):
        c = lower(build(gn)).compile(
            "pallas", pipeline=_grid_pipeline(fused, tiled))
        c(**gd)  # compile
        t0 = time.perf_counter()
        out = c(**gd)
        np.asarray(out["w_out"])
        grid_times[name] = time.perf_counter() - t0
        kernels[name] = c.report["grid_kernels"]
        blocks[name] = [e["block_shape"] for e in c.report["grid_converted"]]
        np.testing.assert_allclose(np.asarray(out["x_out"]), gx_ref,
                                   rtol=5e-2, atol=5e-1)
        np.testing.assert_allclose(np.asarray(out["w_out"]), gw_ref,
                                   rtol=5e-2, atol=5e-1)
    assert len(kernels["unfused"]) == 4 and len(kernels["fused"]) == 3

    report("gemver_grid_unfused_ms", grid_times["unfused"] * 1e3,
           f"n={gn}; kernels={kernels['unfused']}", backend="pallas")
    report("gemver_grid_fused_ms", grid_times["fused"] * 1e3,
           f"n={gn}; ger pair fused, B1 in-kernel, blocks="
           f"{blocks['fused'][0]}; speedup "
           f"{grid_times['unfused']/grid_times['fused']:.2f}x vs unfused",
           backend="pallas", block_shape=blocks["fused"][0])
    report("gemver_grid_untiled_ms", grid_times["untiled"] * 1e3,
           f"n={gn}; fused but 1-element blocks {blocks['untiled'][0]}; "
           f"tiled speedup "
           f"{grid_times['untiled']/grid_times['fused']:.2f}x",
           backend="pallas")
    assert grid_times["fused"] < grid_times["untiled"], \
        "tiled grid variant must beat the 1-element-block grid variant"

    # fused-DAG chain: ger->ger->gemv as ONE grid kernel (accumulate gemv)
    # vs the pairwise-fused baseline (ger pair fused, row-streaming gemv
    # as its own kernel, B2 round-tripping through HBM between them).
    # Sized where the avoided n^2 round-trip dominates: below ~256 the
    # pairwise row-gemv block is too cheap for the fusion win to show.
    cn = 384
    cd = {k: rng.standard_normal((cn, cn) if k == "A" else cn
                                 ).astype(np.float32)
          for k in ("A", "u1", "v1", "u2", "v2", "xw")}
    B = cd["A"] + np.outer(cd["u1"], cd["v1"]) + np.outer(cd["u2"], cd["v2"])
    w_ref = 1.1 * B @ cd["xw"]
    chain_times, chain_kernels = {}, {}
    reps = 5  # this pair feeds a hard CI comparison gate: average it
    for name, pref in (("dag", ("accumulate", "generic")),
                       ("pairwise", ("generic",))):
        c = lower(build_chain(cn)).compile(
            "pallas", pipeline=_chain_pipeline(name, pref))
        c(**cd)  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = c(**cd)
            np.asarray(out["w_out"])
        chain_times[name] = (time.perf_counter() - t0) / reps
        chain_kernels[name] = c.report["grid_kernels"]
        np.testing.assert_allclose(np.asarray(out["w_out"]), w_ref,
                                   rtol=5e-2, atol=5e-1)
    assert len(chain_kernels["dag"]) == 1, \
        f"chain must fuse to ONE grid kernel, got {chain_kernels['dag']}"
    assert len(chain_kernels["pairwise"]) >= 2
    report("gemver_chain_dag_ms", chain_times["dag"] * 1e3,
           f"n={cn}; ger->ger->gemv as ONE kernel "
           f"{chain_kernels['dag']}; speedup "
           f"{chain_times['pairwise']/chain_times['dag']:.2f}x vs pairwise",
           backend="pallas", grid_kernels=len(chain_kernels["dag"]))
    report("gemver_chain_pairwise_ms", chain_times["pairwise"] * 1e3,
           f"n={cn}; pairwise-fused baseline, kernels="
           f"{chain_kernels['pairwise']}", backend="pallas",
           grid_kernels=len(chain_kernels["pairwise"]))


def _grid_pipeline(fused: bool, tiled: bool = True,
                   tile_size: int = None) -> PassManager:
    passes = [SetExpansionPreferencePass(("generic",)),
              ExpandLibraryNodesPass()]
    if fused:
        passes.append(MapFusionPass())
    if tiled:
        defaults = GridConversionPass.default_tiles("pallas", True)
        passes.append(MapTilingPass(tile_size=tile_size)
                      if tile_size else
                      MapTilingPass(tile_size=defaults.get("minor"),
                                    second_size=defaults.get("second")))
    passes.append(GridConversionPass())
    return PassManager(passes, name=f"grid_f{int(fused)}_t{int(tiled)}"
                                    f"_{tile_size or 'auto'}")


def _chain_pipeline(name: str, pref) -> PassManager:
    defaults = GridConversionPass.default_tiles("pallas", True)
    return PassManager([
        SetExpansionPreferencePass(tuple(pref)),
        ExpandLibraryNodesPass(),
        MapFusionPass(),
        MapTilingPass(tile_size=defaults.get("minor"),
                      second_size=defaults.get("second")),
        GridConversionPass(),
    ], name=f"chain_{name}")


def calibrate(report, small: bool = False):
    """Sweep the minor (lane) tile size for the fused grid ladder on the
    current backend and record the measured winner — the numbers the
    GridConversion cost model's static thresholds should be tuned to."""
    rng = np.random.default_rng(1)
    gn = 64 if small else GRID_N
    gd = {k: rng.standard_normal((gn, gn) if k == "A" else gn
                                 ).astype(np.float32)
          for k in ("A", "u1", "v1", "u2", "v2", "y", "z")}
    best, times = None, {}
    for t in (8, 16, 32, 64, 128):
        if t > gn:
            continue
        c = lower(build(gn)).compile(
            "pallas", pipeline=_grid_pipeline(True, True, tile_size=t))
        c(**gd)  # compile
        t0 = time.perf_counter()
        out = c(**gd)
        np.asarray(out["w_out"])
        times[t] = time.perf_counter() - t0
        blk = c.report["grid_converted"][0]["block_shape"]
        report(f"gemver_calibrate_tile{t}_ms", times[t] * 1e3,
               f"n={gn}; fused grid, minor tile {t}, blocks {blk}",
               backend="pallas")
        if best is None or times[t] < times[best]:
            best = t
    report("gemver_calibrate_best_tile", best,
           f"n={gn}; measured crossover of the minor-tile sweep "
           f"{sorted(times)}", backend="pallas")
