"""Paper Fig. 19: StencilFlow programs (jacobi3d, diffusion2d/3d) and the
two-iteration diffusion chain (Fig. 17) with fused multi-stage kernel.
CPU interpret-mode wall-clock is reported for relative comparison plus the
analytic GOp count; absolute GOp/s belongs to real TPU hardware."""
from __future__ import annotations

import time

import numpy as np

import repro.kernels  # noqa: F401
from repro.frontends.stencil import build_stencil_program
from repro.kernels import stencil
from repro.pipeline import lower
from repro.transforms import DeviceOffload, StreamingComposition

# reduced domains (paper: 2^17 x 4096 and 2^15 x 128 x 128)
DOM2D = (2048, 512)
DOM3D = (128, 64, 64)


def _gops(n_points, flops_per_point, seconds):
    return n_points * flops_per_point / seconds / 1e9


def run(report):
    rng = np.random.default_rng(0)
    a2 = rng.standard_normal(DOM2D).astype(np.float32)
    co = np.array([0.2, 0.1, 0.15, 0.25, 0.3], np.float32)
    out = stencil.diffusion2d(a2, co, bh=256)          # warm
    t0 = time.perf_counter()
    out = stencil.diffusion2d(a2, co, bh=256)
    np.asarray(out)
    t2 = time.perf_counter() - t0
    report("stencil_diffusion2d_ms", t2 * 1e3,
           f"{_gops(a2.size, 9, t2):.2f} GOp/s CPU-interp; dom={DOM2D}")

    a3 = rng.standard_normal(DOM3D).astype(np.float32)
    t0 = time.perf_counter()
    out = stencil.jacobi3d(a3, bd=16)
    np.asarray(out)
    t3 = time.perf_counter() - t0
    report("stencil_jacobi3d_ms", t3 * 1e3,
           f"{_gops(a3.size, 8, t3):.2f} GOp/s CPU-interp; dom={DOM3D}")

    t0 = time.perf_counter()
    out = stencil.diffusion3d(a3, 0.1, bd=16)
    np.asarray(out)
    td3 = time.perf_counter() - t0
    report("stencil_diffusion3d_ms", td3 * 1e3,
           f"{_gops(a3.size, 13, td3):.2f} GOp/s CPU-interp")

    # Fig.-17 two-iteration diffusion program through the full stack
    spec = {
        "name": "diff2x", "dimensions": [512, 256], "outputs": ["d"],
        "inputs": {"a": {"data_type": "float32", "input_dims": ["j", "k"]}},
        "program": {
            "b": {"computation": "b = c0*a[j,k] + c1*a[j-1,k] + c2*a[j+1,k]"
                                 " + c3*a[j,k-1] + c4*a[j,k+1]"},
            "d": {"computation": "d = c0*b[j,k] + c1*b[j-1,k] + c2*b[j+1,k]"
                                 " + c3*b[j,k-1] + c4*b[j,k+1]"},
        }}
    sdfg = build_stencil_program(spec)
    sdfg.apply(DeviceOffload)
    v0 = sdfg.off_chip_volume()
    sdfg.apply(StreamingComposition)
    v1 = sdfg.off_chip_volume()
    c = lower(sdfg).compile("pallas")
    a = rng.standard_normal((512, 256)).astype(np.float32)
    c(a=a, b_coeffs=co, d_coeffs=co)
    t0 = time.perf_counter()
    out = c(a=a, b_coeffs=co, d_coeffs=co)
    np.asarray(out["d"])
    tc = time.perf_counter() - t0
    report("stencilflow_chain_ms", tc * 1e3,
           f"fused={c.report['fused_regions']}; volume {v0}->{v1} B "
           f"({v0/v1:.2f}x; intermediate b never leaves VMEM)")
