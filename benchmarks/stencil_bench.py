"""Paper Fig. 19: StencilFlow programs (jacobi3d, diffusion2d/3d) and the
two-iteration diffusion chain (Fig. 17) with fused multi-stage kernel.
CPU interpret-mode wall-clock is reported for relative comparison plus the
analytic GOp count; absolute GOp/s belongs to real TPU hardware. The
5-point star additionally runs through the *generated* grid path
(GridConversion of a mapped-tasklet stencil) against its jnp/vmap
lowering."""
from __future__ import annotations

import time

import numpy as np

import repro.kernels  # noqa: F401
from repro.core.memlet import Memlet, Subset
from repro.core.sdfg import SDFG
from repro.core.symbolic import sym
from repro.frontends.stencil import build_stencil_program
from repro.kernels import stencil
from repro.pipeline import (GridConversionPass, MapTilingPass, PassManager,
                            lower)
from repro.transforms import DeviceOffload, StreamingComposition

# reduced domains (paper: 2^17 x 4096 and 2^15 x 128 x 128)
DOM2D = (2048, 512)
DOM3D = (128, 64, 64)
STAR_DOM = (130, 130)     # generated-grid star stencil (interpret mode)


def _gops(n_points, flops_per_point, seconds):
    return n_points * flops_per_point / seconds / 1e9


def _star_sdfg(n, m):
    """5-point star over interior points as a mapped tasklet — the shape
    GridConversion lowers to one partial-coverage grid kernel."""
    s = SDFG("star5")
    s.add_array("a", (n, m), "float32")
    s.add_array("b", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    offs = {"c": (0, 0), "nn": (-1, 0), "ss": (1, 0),
            "ww": (0, -1), "ee": (0, 1)}
    st.add_mapped_tasklet(
        "star", {"i": (1, n - 1), "j": (1, m - 1)},
        inputs={kk: Memlet.simple("a", Subset.indices([i + di, j + dj]))
                for kk, (di, dj) in offs.items()},
        outputs={"o": Memlet.simple("b", Subset.indices([i, j]))},
        fn=lambda c, nn, ss, ww, ee: 0.5 * c + 0.125 * (nn + ss + ww + ee))
    return s


def run(report, small: bool = False):
    dom2d = (512, 128) if small else DOM2D
    dom3d = (32, 16, 16) if small else DOM3D
    star_dom = (34, 34) if small else STAR_DOM
    rng = np.random.default_rng(0)
    a2 = rng.standard_normal(dom2d).astype(np.float32)
    co = np.array([0.2, 0.1, 0.15, 0.25, 0.3], np.float32)
    bh = 128 if small else 256
    out = stencil.diffusion2d(a2, co, bh=bh)           # warm
    t0 = time.perf_counter()
    out = stencil.diffusion2d(a2, co, bh=bh)
    np.asarray(out)
    t2 = time.perf_counter() - t0
    report("stencil_diffusion2d_ms", t2 * 1e3,
           f"{_gops(a2.size, 9, t2):.2f} GOp/s CPU-interp; dom={dom2d}")

    a3 = rng.standard_normal(dom3d).astype(np.float32)
    bd = 8 if small else 16
    t0 = time.perf_counter()
    out = stencil.jacobi3d(a3, bd=bd)
    np.asarray(out)
    t3 = time.perf_counter() - t0
    report("stencil_jacobi3d_ms", t3 * 1e3,
           f"{_gops(a3.size, 8, t3):.2f} GOp/s CPU-interp; dom={dom3d}")

    t0 = time.perf_counter()
    out = stencil.diffusion3d(a3, 0.1, bd=bd)
    np.asarray(out)
    td3 = time.perf_counter() - t0
    report("stencil_diffusion3d_ms", td3 * 1e3,
           f"{_gops(a3.size, 13, td3):.2f} GOp/s CPU-interp")

    # generated grid path: the star stencil map as ONE partial-coverage
    # grid kernel — multi-dim sublane x lane tiles with windowed halo
    # reads — against the 1-element-block grid and the jnp/vmap lowering
    sn, sm = star_dom
    sa = rng.standard_normal((sn, sm)).astype(np.float32)
    cg = lower(_star_sdfg(sn, sm)).compile("pallas")
    assert cg.report["grid_kernels"] == ["star_tiled"]
    star_blocks = cg.report["grid_converted"][0]["block_shape"]
    cu = lower(_star_sdfg(sn, sm)).compile(
        "pallas", pipeline=PassManager([GridConversionPass()],
                                       name="star_untiled"))
    assert cu.report["grid_kernels"] == ["star"]
    cj = lower(_star_sdfg(sn, sm)).compile("jnp")
    cg(a=sa)  # compile
    t0 = time.perf_counter()
    og = cg(a=sa)
    np.asarray(og["b"])
    tg = time.perf_counter() - t0
    cu(a=sa)
    t0 = time.perf_counter()
    ou = cu(a=sa)
    np.asarray(ou["b"])
    tu = time.perf_counter() - t0
    cj(a=sa)
    t0 = time.perf_counter()
    oj = cj(a=sa)
    np.asarray(oj["b"])
    tj = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(og["b"]), np.asarray(oj["b"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ou["b"]), np.asarray(oj["b"]),
                               rtol=1e-5, atol=1e-6)
    report("stencil_star_grid_ms", tg * 1e3,
           f"dom={star_dom}; generated grid kernel, blocks={star_blocks}; "
           f"tiled speedup {tu/tg:.2f}x vs 1-element blocks",
           backend="pallas", block_shape=star_blocks)
    report("stencil_star_grid_untiled_ms", tu * 1e3,
           f"dom={star_dom}; 1-element-block grid kernel",
           backend="pallas")
    report("stencil_star_jnp_ms", tj * 1e3,
           f"dom={star_dom}; structural vmap lowering")
    assert tg < tu, \
        "tiled grid variant must beat the 1-element-block grid variant"

    # Fig.-17 two-iteration diffusion program through the full stack
    chain_dom = [128, 64] if small else [512, 256]
    spec = {
        "name": "diff2x", "dimensions": chain_dom, "outputs": ["d"],
        "inputs": {"a": {"data_type": "float32", "input_dims": ["j", "k"]}},
        "program": {
            "b": {"computation": "b = c0*a[j,k] + c1*a[j-1,k] + c2*a[j+1,k]"
                                 " + c3*a[j,k-1] + c4*a[j,k+1]"},
            "d": {"computation": "d = c0*b[j,k] + c1*b[j-1,k] + c2*b[j+1,k]"
                                 " + c3*b[j,k-1] + c4*b[j,k+1]"},
        }}
    sdfg = build_stencil_program(spec)
    sdfg.apply(DeviceOffload)
    v0 = sdfg.off_chip_volume()
    sdfg.apply(StreamingComposition)
    v1 = sdfg.off_chip_volume()
    c = lower(sdfg).compile("pallas")
    a = rng.standard_normal(tuple(chain_dom)).astype(np.float32)
    c(a=a, b_coeffs=co, d_coeffs=co)
    t0 = time.perf_counter()
    out = c(a=a, b_coeffs=co, d_coeffs=co)
    np.asarray(out["d"])
    tc = time.perf_counter() - t0
    report("stencilflow_chain_ms", tc * 1e3,
           f"fused={c.report['fused_regions']}; volume {v0}->{v1} B "
           f"({v0/v1:.2f}x; intermediate b never leaves VMEM)")


def calibrate(report, small: bool = False):
    """Sweep the sublane (second-minor) tile of the star grid kernel on
    the current backend; record per-tile times and the measured winner."""
    sn, sm = (34, 34) if small else STAR_DOM
    sa = np.random.default_rng(2).standard_normal((sn, sm)).astype(np.float32)
    best, times = None, {}
    for t in (2, 4, 8, 16, 32):
        if t >= sn - 2:
            continue
        pm = PassManager(
            [MapTilingPass(tile_sizes={"j": sm - 2, "i": t}),
             GridConversionPass()], name=f"star_tile{t}")
        c = lower(_star_sdfg(sn, sm)).compile("pallas", pipeline=pm)
        c(a=sa)  # compile
        t0 = time.perf_counter()
        out = c(a=sa)
        np.asarray(out["b"])
        times[t] = time.perf_counter() - t0
        blk = c.report["grid_converted"][0]["block_shape"] \
            if c.report["grid_converted"] else None
        report(f"stencil_calibrate_tile{t}_ms", times[t] * 1e3,
               f"dom=({sn},{sm}); star grid, sublane tile {t}, "
               f"blocks {blk}", backend="pallas")
        if best is None or times[t] < times[best]:
            best = t
    report("stencil_calibrate_best_tile", best,
           f"dom=({sn},{sm}); measured crossover of sublane sweep "
           f"{sorted(times)}", backend="pallas")
