"""Serving throughput: compiled decode step vs ``jax.jit(decode_step)``.

For each config the baseline decodes against the full dense
``max_model_len`` cache through ``jax.jit(model.decode_step)`` — the
straightforward serving loop — while the compiled path runs the
:mod:`repro.serving` scheduler: paged KV cache, (B, ctx) shape-bucketed
SDFG steps with the attention lowered to Pallas grid kernels, donated
page buffers, and contexts bounded by the live sequences instead of the
model limit.

Entries (tokens/sec, higher is better):
  ``serve_<arch>_b<B>_baseline_tps`` / ``serve_<arch>_b<B>_compiled_tps``
with p50/p99 per-token decode latency and the grid-kernel count as
extras. At batch >= 64 the run itself asserts the compiled path beats
the baseline for the attention configs (starcoder2, gemma3) — the
paper-style claim this PR gates in CI.

The ``*_bf16_tps`` row compiles with dtype-aware sublane tiling
(``second_size=None``) so the grid blocks show the bf16 16-row packing in
their ``derived`` record; ``--small`` swaps it for a fp32 row at B=16
(8-row sublanes) so the smoke run still converts a grid kernel.

The ``*_faulted_tps`` row (ISSUE 8) reruns the compiled path under a
combined fault plan — one injected step exception, a forced page-pressure
window (>= 1 preemption + re-prefill), one NaN-logits step — and records
recovery overhead: the run asserts faulted throughput stays within 1.5x
of the fault-free run at the same batch (the ``fault_free_tps`` extra,
gated again by check_bench against the committed baseline).

The ``*_sharded_tps`` and ``*_shrink_recovery_tps`` rows (ISSUE 9) run
the scheduler with the decode step partitioned across a 2-host mesh
(``shard_map`` over the ShardMapPass-partitioned SDFG). Both record the
in-run unsharded throughput (``unsharded_tps`` extra) so check_bench can
bound the sharding overhead; the shrink row kills a host mid-decode
(``Scheduler.shrink``), records ``resharding_events``, and asserts the
streams stay byte-identical. Requires >= 2 jax devices — CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

ARCHS = ("starcoder2-3b", "gemma3-4b", "rwkv6-7b")
PROMPT, NEW = 16, 24
PAGE = 16
#: compiled must beat baseline at these batches (attention configs only;
#: rwkv has no attention, so the paged-context win does not apply)
ASSERT_BATCHES = (64, 256)
ASSERT_ARCHS = ("starcoder2-3b", "gemma3-4b")


def _slug(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def _baseline_tps(model, params, prompts, new_tokens: int,
                  max_model_len: int) -> float:
    import jax
    import jax.numpy as jnp
    B = prompts.shape[0]
    cache = model.init_cache(B, max_model_len)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, jnp.asarray(prompts, jnp.int32))
    toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits, cache = step(params, cache, toks)  # warm the decode shape
    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(new_tokens):
        logits, cache = step(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    return B * new_tokens / (time.perf_counter() - t0)


def _compiled_run(model, params, prompts, new_tokens: int,
                  max_model_len: int, **sched_kw):
    """Returns (tokens/sec, p50 ms, p99 ms, report) for one scheduler run."""
    from repro.serving import Scheduler
    B = prompts.shape[0]
    n_pages = B * ((PROMPT + new_tokens) // PAGE + 1) + 1
    sched = Scheduler(model, params, max_slots=B, page_size=PAGE,
                      n_pages=n_pages, max_model_len=max_model_len,
                      prefill_chunk=PROMPT, **sched_kw)
    for b in range(B):
        sched.submit(list(map(int, prompts[b])), new_tokens)
    reqs = sched.run()
    sched.check_invariants()
    # steady state: drop the prefill token and the compile-warmup steps
    steady: List[float] = []
    for r in reqs:
        steady.extend(r.token_times[3:])
    if not steady:
        steady = [t for r in reqs for t in r.token_times[1:]]
    med = float(np.median(steady))
    report = sched.compiler._steps[max(sched.compiler._steps)].report
    return (B / med, float(np.percentile(steady, 50) * 1e3),
            float(np.percentile(steady, 99) * 1e3), report)


def _grid_derived(report) -> str:
    conv = report.get("grid_converted") or []
    if not conv:
        return "grid_kernels=0"
    shape = conv[0].get("block_shape")
    return f"grid_kernels={len(conv)} blocks={shape}"


def run(report, small: bool = False):
    import jax
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM

    new_tokens = 8 if small else NEW
    max_model_len = 128 if small else 512
    batches = (1, 8) if small else (1, 8, 64, 256)
    rng = np.random.RandomState(0)

    results = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = TransformerLM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts_all = rng.randint(0, cfg.vocab, size=(max(batches), PROMPT))
        for B in batches:
            prompts = prompts_all[:B]
            base = _baseline_tps(model, params, prompts, new_tokens,
                                 max_model_len)
            tps, p50, p99, rep = _compiled_run(
                model, params, prompts, new_tokens, max_model_len)
            nk = len(rep.get("grid_kernels", []))
            slug = _slug(arch)
            report(f"serve_{slug}_b{B}_baseline_tps", base,
                   derived=f"dense ctx={max_model_len}", backend="pallas")
            report(f"serve_{slug}_b{B}_compiled_tps", tps,
                   derived=_grid_derived(rep), backend="pallas",
                   p50_ms=p50, p99_ms=p99, grid_kernels=nk)
            results[(arch, B)] = (base, tps, nk)

    for arch in ASSERT_ARCHS:
        for B in ASSERT_BATCHES:
            if (arch, B) not in results:
                continue
            base, tps, nk = results[(arch, B)]
            assert tps > base, (
                f"{arch} b{B}: compiled {tps:.0f} tok/s does not beat "
                f"baseline {base:.0f} tok/s")
            assert nk >= 1, (
                f"{arch} b{B}: compiled step converted no grid kernels")

    # per-dtype sublane row: grid blocks sized by element width, not the
    # calibrated crossover table
    arch = "starcoder2-3b"
    cfg = get_config(arch).reduced()
    if small:  # fp32 -> 8-row sublanes: converts already at B=16
        cfg = dataclasses.replace(cfg, activation_dtype="float32")
        B, tag = 16, "f32"
    else:      # bf16 -> 16-row sublanes
        B, tag = 64, "bf16"
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = rng.randint(0, cfg.vocab, size=(B, PROMPT))
    tps, p50, p99, rep = _compiled_run(model, params, prompts, new_tokens,
                                       max_model_len,
                                       dtype_aware_sublanes=True)
    nk = len(rep.get("grid_kernels", []))
    assert nk >= 1, f"dtype-aware {tag} row converted no grid kernels"
    report(f"serve_{_slug(arch)}_b{B}_{tag}_tps", tps,
           derived=_grid_derived(rep), backend="pallas",
           p50_ms=p50, p99_ms=p99, grid_kernels=nk)

    _faulted_row(report, small, new_tokens, max_model_len)
    _sharded_rows(report, small, new_tokens)


def _sharded_rows(report, small: bool, new_tokens: int):
    """2-host sharded decode throughput + live-shrink recovery, each with
    the unsharded throughput of the same workload as in-run comparator."""
    import dataclasses as dc
    import jax
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.serving import Scheduler

    if jax.device_count() < 2:
        print("serve: < 2 devices — skipping sharded rows (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=2 before running)")
        return

    arch = "starcoder2-3b"
    B = 8 if small else 16
    mml = 64 if small else 128
    # sharded exactness is byte-level only without cross-batch reductions;
    # keep activations f32 so the comparator is exact, not approximate
    cfg = dc.replace(get_config(arch).reduced(),
                     activation_dtype="float32")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, size=PROMPT)))
               for _ in range(B)]
    ppr = (PROMPT + new_tokens) // PAGE + 1
    n_pages = B * ppr + 2  # one null page per shard

    def one(n_shards=1, shrink_at=None):
        sched = Scheduler(model, params, max_slots=B, page_size=PAGE,
                          n_pages=n_pages, max_model_len=mml,
                          prefill_chunk=PROMPT, cache_dtype="float32",
                          n_shards=n_shards)
        for p in prompts:
            sched.submit(p, new_tokens)
        t0 = time.perf_counter()
        if shrink_at is not None:
            for _ in range(shrink_at):
                sched.step()
            sched.shrink(1)
        reqs = sched.run()
        wall = time.perf_counter() - t0
        sched.check_invariants()
        total = sum(len(r.tokens_out) for r in reqs)
        return (total / wall, {r.rid: list(r.tokens_out) for r in reqs},
                sched)

    base_tps, base_streams, _ = one()
    tps, got, sched = one(n_shards=2)
    assert got == base_streams, "sharded streams diverged from unsharded"
    sm = sched.compiler._steps[max(sched.compiler._steps)].report.get(
        "shard_map") or {}
    report(f"serve_{_slug(arch)}_sharded_tps", tps, backend="pallas",
           derived=f"n_shards=2 sharded={sm.get('sharded')}",
           unsharded_tps=base_tps, batch=B, n_shards=2)

    tps, got, sched = one(n_shards=2, shrink_at=3)
    assert got == base_streams, "streams diverged after mesh shrink"
    evs = [e for e in sched.events if e["kind"] == "mesh_shrink"]
    pre = [e for e in sched.events if e["kind"] == "shrink_preempt"]
    assert evs, "shrink produced no mesh_shrink event"
    report(f"serve_{_slug(arch)}_shrink_recovery_tps", tps,
           backend="pallas",
           derived=f"2->1 hosts, {len(pre)} preempted",
           unsharded_tps=base_tps, batch=B,
           resharding_events=len(evs), preempted=len(pre))


def _faulted_row(report, small: bool, new_tokens: int, max_model_len: int):
    """Recovery overhead: the combined ISSUE-8 fault plan vs fault-free
    at the same batch, wall-clock tokens/sec over the whole run."""
    import jax
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.serving import FaultInjector, Scheduler, ServeFaultPlan

    arch = "starcoder2-3b"
    B = 8 if small else 64
    cfg = get_config(arch).reduced()
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    # staggered one-page prompts: lanes cross their first page boundary
    # at different steps, so the pressure window hits a live crossing
    plens = rng.randint(8, PAGE + 1, size=B)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, size=p)))
               for p in plens]
    n_pages = B * ((PAGE + new_tokens) // PAGE + 1) + 1

    def one(injector=None):
        sched = Scheduler(model, params, max_slots=B, page_size=PAGE,
                          n_pages=n_pages, max_model_len=max_model_len,
                          prefill_chunk=PAGE, injector=injector)
        if injector is not None:
            # compile time is a one-off; the row measures steady-state
            # recovery overhead, so warm the fallback rung off-clock
            for ctx in (2 * PAGE, 4 * PAGE):
                if ctx <= max_model_len:
                    sched.compiler.fallback_for(B, ctx)
        for p in prompts:
            sched.submit(p, new_tokens)
        t0 = time.perf_counter()
        reqs = sched.run()
        wall = time.perf_counter() - t0
        sched.check_invariants()
        total = sum(len(r.tokens_out) for r in reqs)
        return total / wall, sched

    clean_tps, _ = one()
    plan = ServeFaultPlan(step_exception_at=1, page_pressure_at=2,
                          page_pressure_release_at=6, nan_logits_at=4)
    tps, sched = one(FaultInjector(plan))
    st = sched.stats()
    assert st["preemptions"] >= 1, "pressure window caused no preemption"
    assert st["fallback_steps"] >= 1, "no fallback re-run happened"
    assert all(r.finish_reason == "max_tokens" for r in sched.finished)
    overhead = clean_tps / tps
    assert overhead <= 1.5, (
        f"faulted run {tps:.0f} tok/s is {overhead:.2f}x slower than "
        f"fault-free {clean_tps:.0f} tok/s (budget 1.5x)")
    report(f"serve_{_slug(arch)}_faulted_tps", tps, backend="pallas",
           derived=(f"preemptions={st['preemptions']} "
                    f"fallback_steps={st['fallback_steps']}"),
           fault_free_tps=clean_tps, batch=B,
           preemptions=st["preemptions"],
           fallback_steps=st["fallback_steps"])


if __name__ == "__main__":
    import subprocess
    import sys
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "benchmarks.run", "--only", "serve"]
        + sys.argv[1:]))
