"""Deep stencil pipeline through halo-aware MapFusion: a 4-stage 1-D
jacobi chain compiled to ONE Pallas grid kernel.

Each stage reads its predecessor at ``i-1, i, i+1`` — the write-order =
read-order rule lets MapFusion replicate producers per offset
(content-deduplicated: 1+3+5+7 = 16 tasklets for 4 stages at radius 1)
so the three intermediates never leave VMEM.  The per-stage baseline is
the identical pipeline minus MapFusionPass: four grid kernels with the
intermediates materialized between them.  The jnp/vmap lowering
cross-validates both.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.memlet import Memlet, Subset
from repro.core.sdfg import SDFG
from repro.core.symbolic import sym
from repro.pipeline import (ExpandLibraryNodesPass, GridConversionPass,
                            MapTilingPass, PassManager, PipelineFusionPass,
                            SetExpansionPreferencePass, VectorizationPass,
                            lower)

N = 8704          # interior after 4 stages: 8192 (all extents % 64 == 0)
N_SMALL = 1088    # interior after 4 stages: 576
STAGES = 4
MARGIN = 64       # stage k computes [MARGIN*(k+1), n - MARGIN*(k+1))
REPS = 5


def _chain_sdfg(n, stages=STAGES):
    s = SDFG("jacobi_chain")
    s.add_array("a", (n,), "float32")
    s.add_array("b", (n,), "float32")
    names = ["a"]
    for k in range(1, stages):
        s.add_transient(f"t{k}", (n,), "float32")
        names.append(f"t{k}")
    names.append("b")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    node_of = {}
    for k in range(stages):
        src, dst = names[k], names[k + 1]
        lo, hi = MARGIN * (k + 1), n - MARGIN * (k + 1)
        _, _, ex = st.add_mapped_tasklet(
            f"jacobi{k}", {"i": (lo, hi)},
            inputs={"w": Memlet.simple(src, Subset.indices([i - 1])),
                    "c": Memlet.simple(src, Subset.indices([i])),
                    "e": Memlet.simple(src, Subset.indices([i + 1]))},
            outputs={"o": Memlet.simple(dst, Subset.indices([i]))},
            fn=lambda w, c, e: 0.25 * w + 0.5 * c + 0.25 * e,
            input_nodes={src: node_of[src]} if src in node_of else None)
        node_of[dst] = next(e.dst for e in st.out_edges(ex)
                            if e.memlet.data == dst)
    return s


def _reference(a, stages=STAGES):
    n = a.shape[0]
    cur = a
    for k in range(stages):
        lo, hi = MARGIN * (k + 1), n - MARGIN * (k + 1)
        nxt = np.zeros_like(cur)
        nxt[lo:hi] = (0.25 * cur[lo - 1:hi - 1] + 0.5 * cur[lo:hi]
                      + 0.25 * cur[lo + 1:hi + 1])
        cur = nxt
    return cur


def _perstage_pipeline():
    """The pallas default pipeline with MapFusionPass removed: every
    stage stays its own scope and converts to its own grid kernel."""
    tiles = GridConversionPass.default_tiles("pallas", True)
    return PassManager([
        SetExpansionPreferencePass(("pallas", "xla", "generic")),
        PipelineFusionPass(interpret=True),
        ExpandLibraryNodesPass(),
        VectorizationPass(),
        MapTilingPass(tile_size=tiles.get("minor"),
                      second_size=tiles.get("second")),
        GridConversionPass(),
    ], name="jacobi_perstage")


def _time(fn, *args, **kwargs):
    fn(*args, **kwargs)  # compile / warm
    best = float("inf")
    out = None
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        np.asarray(out["b"])
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(report, small: bool = False):
    n = N_SMALL if small else N
    rng = np.random.default_rng(7)
    a = rng.standard_normal((n,)).astype(np.float32)

    cf = lower(_chain_sdfg(n)).compile("pallas")
    assert len(cf.report["grid_kernels"]) == 1, \
        f"fused chain must be ONE grid kernel, got {cf.report['grid_kernels']}"
    blocks = cf.report["grid_converted"][0]["block_shape"]
    tasklets = cf.report["grid_converted"][0].get("tasklets")

    cp = lower(_chain_sdfg(n)).compile("pallas",
                                       pipeline=_perstage_pipeline())
    assert len(cp.report["grid_kernels"]) == STAGES, \
        f"per-stage baseline must be {STAGES} kernels, " \
        f"got {cp.report['grid_kernels']}"

    cj = lower(_chain_sdfg(n)).compile("jnp")

    of, tf = _time(cf, a=a)
    op, tp = _time(cp, a=a)
    oj, tj = _time(cj, a=a)

    ref = _reference(a)
    np.testing.assert_allclose(np.asarray(of["b"]), ref,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op["b"]), ref,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(oj["b"]), ref,
                               rtol=1e-4, atol=1e-5)

    report("jacobi_chain_fused_ms", tf * 1e3,
           f"n={n}; {STAGES} stages as ONE grid kernel "
           f"({tasklets} tasklets after halo replication, blocks={blocks}); "
           f"{tp/tf:.2f}x vs per-stage",
           backend="pallas", grid_kernels=1, block_shape=blocks)
    report("jacobi_chain_perstage_ms", tp * 1e3,
           f"n={n}; one grid kernel per stage, intermediates materialized",
           backend="pallas", grid_kernels=STAGES)
    report("jacobi_chain_jnp_ms", tj * 1e3,
           f"n={n}; structural vmap lowering")
    assert tf < tp, \
        "fused chain must beat the per-stage baseline"
