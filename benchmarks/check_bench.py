"""CI benchmark assertions over BENCH_<name>.json records.

Three gates:

1. **Grid conversion actually happened**: the tiled+fused grid variants
   ran (their entries exist), their derived records carry multi-dim
   blocks (``blocks=[s, l]`` with a lane dim >= 8), and within the same
   run the tiled grid variant beats the 1-element-block grid variant.
2. **Fused DAGs actually fused**: the gemver ger->ger->gemv chain, the
   axpydot two-producer dot, the 4-stage jacobi chain, and the LeNet
   conv+pool stack each ran as ONE grid kernel (their records carry
   ``grid_kernels == 1``), and each fused variant beats its
   pairwise/per-stage baseline measured in the same run.
3. **No >FACTOR regression vs the committed baselines**: entries are
   matched by name against ``--baseline`` records with the same ``small``
   flag; overall machine-speed difference is normalized out with the
   median current/baseline ratio (clamped to [0.5, 4]) so a uniformly
   slower CI runner does not fail the gate while a single kernel
   regressing does.

Usage: python -m benchmarks.check_bench CUR_DIR --baseline BASE_DIR
"""
from __future__ import annotations

import argparse
import json
import os
import re
import statistics
import sys

MODULES = ("axpydot", "gemver", "stencil", "jacobi_chain", "lenet", "serve")
REQUIRED = {
    "gemver": ("gemver_grid_fused_ms", "gemver_grid_untiled_ms",
               "gemver_chain_dag_ms", "gemver_chain_pairwise_ms"),
    "stencil": ("stencil_star_grid_ms", "stencil_star_grid_untiled_ms"),
    "axpydot": ("axpydot_grid_fused_ms", "axpydot_grid_untiled_ms",
                "axpydot_dag_fused_ms"),
    "jacobi_chain": ("jacobi_chain_fused_ms", "jacobi_chain_perstage_ms",
                     "jacobi_chain_jnp_ms"),
    "lenet": ("lenet_convblock_fused_ms", "lenet_convblock_perstage_ms",
              "lenet_convblock_jnp_ms"),
    # serving rows present at every problem size (--small and full)
    "serve": tuple(f"serve_{a}_b{b}_{kind}_tps"
                   for a in ("starcoder2_3b", "gemma3_4b", "rwkv6_7b")
                   for b in (1, 8)
                   for kind in ("baseline", "compiled"))
    + ("serve_starcoder2_3b_faulted_tps",
       # ISSUE-9 elastic rows (CI runs serving with >= 2 simulated hosts)
       "serve_starcoder2_3b_sharded_tps",
       "serve_starcoder2_3b_shrink_recovery_tps"),
}
#: faulted serving throughput must stay within this factor of the
#: fault-free run recorded alongside it (the ISSUE-8 recovery budget)
FAULT_OVERHEAD_BUDGET = 1.5
#: sharded/shrink-recovery throughput must stay within this factor of the
#: in-run unsharded comparator (the ISSUE-9 scale-out overhead budget —
#: simulated hosts on one CPU pay the collective + dispatch cost without
#: any parallel speedup, and the shrink row pays the shrunken mesh's
#: recompile on the clock, so the bound is loose by design)
SHARD_OVERHEAD_BUDGET = 4.0
#: (tiled entry, 1-element-block entry) measured at the same size
TILED_BEATS_UNTILED = (
    ("gemver_grid_fused_ms", "gemver_grid_untiled_ms"),
    ("stencil_star_grid_ms", "stencil_star_grid_untiled_ms"),
)
#: entries that must record a single fused grid kernel (grid_kernels == 1)
SINGLE_KERNEL_DAGS = ("gemver_chain_dag_ms", "axpydot_dag_fused_ms",
                      "jacobi_chain_fused_ms", "lenet_convblock_fused_ms")
#: (fused-DAG entry, pairwise-fused baseline) measured at the same size.
#: The committed margin is ~1.24x on few-ms timings, so the comparison
#: carries a noise allowance: only a clear inversion fails (the
#: structural grid_kernels==1 gate above catches lost fusion exactly).
DAG_BEATS_PAIRWISE = (("gemver_chain_dag_ms", "gemver_chain_pairwise_ms"),
                      ("jacobi_chain_fused_ms", "jacobi_chain_perstage_ms"),
                      ("lenet_convblock_fused_ms",
                       "lenet_convblock_perstage_ms"))
DAG_NOISE_ALLOWANCE = 1.10
#: entries whose derived record must show a multi-dim block shape
MULTIDIM_BLOCKS = ("gemver_grid_fused_ms", "stencil_star_grid_ms")

_BLOCKS_RE = re.compile(r"blocks=\[([\d, ]+)\]")


def _load(path):
    with open(path) as f:
        return {e["name"]: e for e in json.load(f)}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="directory with fresh BENCH_*.json")
    ap.add_argument("--baseline", default=None,
                    help="directory with committed BENCH_*.json baselines")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max allowed normalized slowdown per entry")
    ap.add_argument("--min-ms", type=float, default=5.0,
                    help="ignore entries faster than this (noise)")
    args = ap.parse_args()

    errors = []
    cur = {}
    for mod in MODULES:
        path = os.path.join(args.current, f"BENCH_{mod}.json")
        if not os.path.exists(path):
            errors.append(f"missing {path}: benchmark module did not run")
            continue
        cur[mod] = _load(path)

    for mod, names in REQUIRED.items():
        for name in names:
            if mod in cur and name not in cur[mod]:
                errors.append(f"{mod}: required entry {name!r} missing — "
                              f"the tiled/untiled grid variants did not run")

    for tiled, untiled in TILED_BEATS_UNTILED:
        for mod in cur:
            if tiled in cur[mod] and untiled in cur[mod]:
                tv, uv = cur[mod][tiled]["value"], cur[mod][untiled]["value"]
                if tv >= uv:
                    errors.append(
                        f"{tiled} ({tv:.2f} ms) does not beat "
                        f"{untiled} ({uv:.2f} ms)")

    for name in SINGLE_KERNEL_DAGS:
        for mod in cur:
            if name not in cur[mod]:
                continue
            nk = cur[mod][name].get("grid_kernels")
            if nk != 1:
                errors.append(f"{name}: fused DAG ran as {nk!r} grid "
                              f"kernels, expected exactly 1")

    for dag, pairwise in DAG_BEATS_PAIRWISE:
        for mod in cur:
            if dag in cur[mod] and pairwise in cur[mod]:
                dv, pv = cur[mod][dag]["value"], cur[mod][pairwise]["value"]
                if dv >= pv * DAG_NOISE_ALLOWANCE:
                    errors.append(
                        f"{dag} ({dv:.2f} ms) does not beat the "
                        f"pairwise-fused baseline {pairwise} ({pv:.2f} ms, "
                        f"noise allowance {DAG_NOISE_ALLOWANCE}x)")

    for name in MULTIDIM_BLOCKS:
        for mod in cur:
            if name not in cur[mod]:
                continue
            dims = cur[mod][name].get("block_shape")
            if dims is None:  # older records only carry the prose form
                m = _BLOCKS_RE.search(cur[mod][name].get("derived", ""))
                dims = [int(x) for x in m.group(1).split(",")] if m else None
            if dims is None:
                errors.append(f"{name}: no block_shape in record — "
                              f"grid conversion produced no multi-dim blocks")
                continue
            if len(dims) < 2 or dims[-1] < 8:
                errors.append(f"{name}: block shape {dims} is not a "
                              f"multi-dim lane-aligned block")

    # serving: the compiled decode step must actually contain Pallas grid
    # kernels (the per-layer attention converts) in at least one bucket
    if "serve" in cur:
        if not any(e.get("grid_kernels", 0) >= 1
                   for e in cur["serve"].values()):
            errors.append("serve: no entry records grid_kernels >= 1 — "
                          "the compiled decode step converted no "
                          "attention grid kernels")
        # fault-injected rows: recovery overhead within budget vs the
        # fault-free throughput measured in the same run
        for name, e in cur["serve"].items():
            if not name.endswith("_faulted_tps"):
                continue
            ff = e.get("fault_free_tps")
            if ff is None:
                errors.append(f"{name}: no fault_free_tps extra — the "
                              f"faulted run has no in-run comparator")
            elif ff / e["value"] > FAULT_OVERHEAD_BUDGET:
                errors.append(
                    f"{name}: {e['value']:.0f} tok/s under faults vs "
                    f"{ff:.0f} tok/s fault-free is a {ff / e['value']:.2f}x"
                    f" recovery overhead (> {FAULT_OVERHEAD_BUDGET}x)")
            if not e.get("preemptions"):
                errors.append(f"{name}: fault plan caused no preemption — "
                              f"the page-pressure path was not exercised")
        # elastic rows: sharding overhead bounded vs the in-run unsharded
        # comparator; the shrink row must record a real resharding event
        for name, e in cur["serve"].items():
            if not (name.endswith("_sharded_tps")
                    or name.endswith("_shrink_recovery_tps")):
                continue
            us = e.get("unsharded_tps")
            if us is None:
                errors.append(f"{name}: no unsharded_tps extra — the "
                              f"sharded run has no in-run comparator")
            elif us / e["value"] > SHARD_OVERHEAD_BUDGET:
                errors.append(
                    f"{name}: {e['value']:.0f} tok/s sharded vs {us:.0f} "
                    f"tok/s unsharded is a {us / e['value']:.2f}x overhead "
                    f"(> {SHARD_OVERHEAD_BUDGET}x)")
            if (name.endswith("_shrink_recovery_tps")
                    and not e.get("resharding_events")):
                errors.append(f"{name}: no resharding_events recorded — "
                              f"the mesh never shrank")

    if args.baseline:
        pairs = []
        tps_pairs = []
        for mod in cur:
            bpath = os.path.join(args.baseline, f"BENCH_{mod}.json")
            if not os.path.exists(bpath):
                continue
            base = _load(bpath)
            for name, e in cur[mod].items():
                b = base.get(name)
                if b is None or e.get("small") != b.get("small"):
                    continue
                if name.endswith("_tps"):
                    tps_pairs.append((name, e["value"], b["value"]))
                elif name.endswith("_ms") and b["value"] >= args.min_ms:
                    pairs.append((name, e["value"], b["value"]))
        if pairs:
            med = statistics.median(c / b for _, c, b in pairs)
            norm = min(max(med, 0.5), 4.0)
            for name, c, b in pairs:
                if c / b > args.factor * norm:
                    errors.append(
                        f"{name}: {c:.2f} ms vs baseline {b:.2f} ms is a "
                        f"{c / b:.2f}x slowdown (> {args.factor}x after "
                        f"median normalization {norm:.2f})")
            print(f"regression check: {len(pairs)} matched entries, "
                  f"median ratio {med:.2f}")
        else:
            print("regression check: no comparable baseline entries")
        if tps_pairs:
            # throughput rows: higher is better, so the slowdown ratio and
            # the machine-speed normalization both invert
            med = statistics.median(b / c for _, c, b in tps_pairs)
            norm = min(max(med, 0.5), 4.0)
            for name, c, b in tps_pairs:
                if b / c > args.factor * norm:
                    errors.append(
                        f"{name}: {c:.0f} tok/s vs baseline {b:.0f} tok/s "
                        f"is a {b / c:.2f}x throughput regression (> "
                        f"{args.factor}x after median normalization "
                        f"{norm:.2f})")
            print(f"throughput check: {len(tps_pairs)} matched entries, "
                  f"median ratio {med:.2f}")

    for e in errors:
        print(f"BENCH CHECK FAILED: {e}", file=sys.stderr)
    if not errors:
        print("benchmark checks passed")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
