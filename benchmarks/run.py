"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only axpydot,...]
Prints ``name,value,derived`` CSV lines; exits non-zero on any failure.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import axpydot, gemver, lenet, stencil_bench
    modules = {
        "axpydot": axpydot,        # paper Table 1
        "gemver": gemver,          # paper Table 2
        "lenet": lenet,            # paper Table 3
        "stencil": stencil_bench,  # paper Fig. 19
    }
    only = set(args.only.split(",")) if args.only else set(modules)

    def report(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    failed = []
    print("name,value,derived")
    for name, mod in modules.items():
        if name not in only:
            continue
        try:
            mod.run(report)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}: {e}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
