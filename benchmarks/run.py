"""Benchmark harness: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only axpydot,...]
                                               [--small] [--json OUT]
                                               [--calibrate]
Prints ``name,value,derived`` CSV lines; exits non-zero on any failure.
``--small`` shrinks problem sizes for CI smoke runs; ``--json OUT``
additionally writes one machine-readable ``BENCH_<name>.json`` per module
(entries: name, value, derived, backend, small) so the perf trajectory
can be tracked across commits. ``--calibrate`` additionally runs each
module's tile-size sweep (``calibrate(report, small)``) on the current
backend and records the measured per-tile times plus the winning tile —
the measured numbers the GridConversion cost model's static thresholds
should be recalibrated against.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--small", action="store_true",
                    help="reduced problem sizes (CI smoke)")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT",
                    help="directory to write BENCH_<name>.json records")
    ap.add_argument("--calibrate", action="store_true",
                    help="sweep tile sizes per module and record the "
                         "measured crossover")
    args = ap.parse_args()

    from . import (axpydot, gemver, jacobi_chain, lenet, serve_bench,
                   stencil_bench)
    modules = {
        "axpydot": axpydot,            # paper Table 1
        "gemver": gemver,              # paper Table 2
        "lenet": lenet,                # paper Table 3 + fused conv stack
        "stencil": stencil_bench,      # paper Fig. 19
        "jacobi_chain": jacobi_chain,  # halo-fused deep stencil pipeline
        "serve": serve_bench,          # ROADMAP: serve-heavy-traffic
    }
    only = set(args.only.split(",")) if args.only else set(modules)

    if args.json_out:
        os.makedirs(args.json_out, exist_ok=True)

    failed = []
    print("name,value,derived")
    for name, mod in modules.items():
        if name not in only:
            continue
        entries = []

        def report(bname, value, derived="", backend="jnp", **extra):
            print(f"{bname},{value:.6g},{derived}", flush=True)
            entries.append({"name": bname, "value": float(value),
                            "derived": derived, "backend": backend,
                            "small": bool(args.small), **extra})

        try:
            if "small" in inspect.signature(mod.run).parameters:
                mod.run(report, small=args.small)
            else:
                mod.run(report)
            if args.calibrate and hasattr(mod, "calibrate"):
                mod.calibrate(report, small=args.small)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        if args.json_out and name not in failed:
            # never write partial records for a failed module: a truncated
            # file would read as a complete (fast!) run to perf tracking
            path = os.path.join(args.json_out, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(entries, f, indent=1)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
