"""Paper Table 3: LeNet-5 inference ladder — naive / InputToConstant /
+StreamingComposition. Volumes analytic at the paper's batch=1000; runtime
at batch=100 on CPU (naive jnp vs streamed pallas-interpret)."""
from __future__ import annotations

import time

import numpy as np

import repro.kernels  # noqa: F401
from repro.frontends.ml import build_lenet, init_lenet_params, lenet_reference
from repro.pipeline import (DeviceOffloadPass, InputToConstantPass,
                            StreamingCompositionPass, lower)
from repro.transforms import (DeviceOffload, InputToConstant,
                              StreamingComposition)

PAPER_BATCH = 1000
BENCH_BATCH = 100


def _volumes(batch, params):
    out = {}
    s = build_lenet(batch)
    s.apply(DeviceOffload)
    out["naive"] = s.off_chip_volume()
    s2 = build_lenet(batch)
    s2.apply(InputToConstant, parameters=params)
    s2.apply(DeviceOffload)
    out["const"] = s2.off_chip_volume()
    s2.apply(StreamingComposition)
    out["stream"] = s2.off_chip_volume()
    return out


def run(report):
    params = init_lenet_params()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((BENCH_BATCH, 1, 28, 28)).astype(np.float32)
    exp = np.asarray(lenet_reference(params, x))

    vols = _volumes(PAPER_BATCH, params)

    c1 = lower(build_lenet(BENCH_BATCH)).optimize(
        [DeviceOffloadPass()]).compile("jnp")
    c1(x=x, **params)
    t0 = time.perf_counter()
    o1 = c1(x=x, **params)
    t_naive = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(o1["probs"]), exp, rtol=1e-2,
                               atol=1e-4)

    c2 = lower(build_lenet(BENCH_BATCH)).optimize(
        [InputToConstantPass(parameters=params), DeviceOffloadPass(),
         StreamingCompositionPass()]).compile("pallas")
    c2(x=x)
    t0 = time.perf_counter()
    o2 = c2(x=x)
    t_stream = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(o2["probs"]), exp, rtol=1e-2,
                               atol=1e-4)

    report("lenet_naive_volume_GiB", vols["naive"] / 2**30,
           "paper 0.28 GiB @ batch 1000 (incl. per-tile weight re-streams "
           "we don't model; see EXPERIMENTS §Paper)")
    report("lenet_const_volume_GiB", vols["const"] / 2**30,
           f"ratio {vols['naive']/vols['const']:.2f}x @1000; 1.20x @32 "
           f"(paper 1.27x)")
    report("lenet_stream_volume_GiB", vols["stream"] / 2**30,
           f"ratio {vols['naive']/vols['stream']:.2f}x (paper 1.7x; we "
           f"stream every intermediate)")
    report("lenet_naive_ms", t_naive * 1e3, f"batch={BENCH_BATCH} CPU jnp")
    report("lenet_stream_pallas_ms", t_stream * 1e3,
           f"fused {c2.report['fused_regions']}")
