"""Paper Table 3: LeNet-5 inference ladder — naive / InputToConstant /
+StreamingComposition. Volumes analytic at the paper's batch=1000; runtime
at batch=100 on CPU (naive jnp vs streamed pallas-interpret).

The conv-stack rung compiles LeNet's first conv+relu+maxpool block to ONE
Pallas grid kernel through halo-aware MapFusion: the pool consumer reads
the conv intermediate at the four strided points ``t[2p+u, 2q+v]``, so
MapFusion replicates the conv producer per offset (4 replicas + pool = 5
tasklets) and the feature map never leaves VMEM."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401
from repro.core.memlet import Memlet, Range, Subset
from repro.core.sdfg import SDFG
from repro.core.symbolic import sym
from repro.frontends.ml import build_lenet, init_lenet_params, lenet_reference
from repro.pipeline import (DeviceOffloadPass, ExpandLibraryNodesPass,
                            GridConversionPass, InputToConstantPass,
                            MapTilingPass, PassManager, PipelineFusionPass,
                            SetExpansionPreferencePass,
                            StreamingCompositionPass, VectorizationPass,
                            lower)
from repro.transforms import (DeviceOffload, InputToConstant,
                              StreamingComposition)

PAPER_BATCH = 1000
BENCH_BATCH = 100
CONV_BATCH = 16
K, R, IH = 8, 5, 28          # channels, kernel, input H=W (LeNet conv1)
OH, PH = IH - R + 1, (IH - R + 1) // 2


def _convblock_sdfg(batch):
    """conv(5x5, K ch) + relu + 2x2 maxpool over a (batch,1,28,28) input
    as two mapped tasklets sharing the feature-map access node."""
    s = SDFG("convblock")
    s.add_array("x", (batch, 1, IH, IH), "float32")
    s.add_array("W", (K, 1, R, R), "float32")
    s.add_array("bias", (K,), "float32")
    s.add_transient("t", (batch, K, OH, OH), "float32")
    s.add_array("y", (batch, K, PH, PH), "float32")
    st = s.add_state("main", is_start=True)
    n, k, oh, ow = sym("n"), sym("k"), sym("oh"), sym("ow")
    _, _, ex = st.add_mapped_tasklet(
        "conv", {"n": (0, batch), "k": (0, K), "oh": (0, OH), "ow": (0, OH)},
        inputs={"xs": Memlet.simple("x", Subset([
                    Range.index(n), Range.index(0),
                    Range.make(oh, oh + R), Range.make(ow, ow + R)])),
                "w": Memlet.simple("W", Subset([
                    Range.index(k), Range.index(0),
                    Range.make(0, R), Range.make(0, R)])),
                "bb": Memlet.simple("bias", Subset.indices([k]))},
        outputs={"o": Memlet.simple("t", Subset.indices([n, k, oh, ow]))},
        fn=lambda xs, w, bb: jnp.maximum(jnp.sum(xs * w) + bb, 0.0))
    t_node = next(e.dst for e in st.out_edges(ex) if e.memlet.data == "t")
    ph, pw = sym("ph"), sym("pw")
    st.add_mapped_tasklet(
        "pool", {"n": (0, batch), "k": (0, K), "ph": (0, PH), "pw": (0, PH)},
        inputs={f"p{u}{v}": Memlet.simple("t", Subset.indices(
                    [n, k, 2 * ph + u, 2 * pw + v]))
                for u in (0, 1) for v in (0, 1)},
        outputs={"o": Memlet.simple("y", Subset.indices([n, k, ph, pw]))},
        fn=lambda p00, p01, p10, p11: jnp.maximum(jnp.maximum(p00, p01),
                                                  jnp.maximum(p10, p11)),
        input_nodes={"t": t_node})
    return s


def _convblock_reference(x, W, bias):
    batch = x.shape[0]
    t = np.zeros((batch, K, OH, OH), np.float32)
    for u in range(R):
        for v in range(R):
            t += np.einsum("nij,k->nkij",
                           x[:, 0, u:u + OH, v:v + OH], W[:, 0, u, v])
    t = np.maximum(t + bias[None, :, None, None], 0.0)
    return t.reshape(batch, K, PH, 2, PH, 2).max(axis=(3, 5))


def _perstage_pipeline():
    tiles = GridConversionPass.default_tiles("pallas", True)
    return PassManager([
        SetExpansionPreferencePass(("pallas", "xla", "generic")),
        PipelineFusionPass(interpret=True),
        ExpandLibraryNodesPass(),
        VectorizationPass(),
        MapTilingPass(tile_size=tiles.get("minor"),
                      second_size=tiles.get("second")),
        GridConversionPass(),
    ], name="convblock_perstage")


def _volumes(batch, params):
    out = {}
    s = build_lenet(batch)
    s.apply(DeviceOffload)
    out["naive"] = s.off_chip_volume()
    s2 = build_lenet(batch)
    s2.apply(InputToConstant, parameters=params)
    s2.apply(DeviceOffload)
    out["const"] = s2.off_chip_volume()
    s2.apply(StreamingComposition)
    out["stream"] = s2.off_chip_volume()
    return out


def run(report, small: bool = False):
    bench_batch = 20 if small else BENCH_BATCH
    params = init_lenet_params()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((bench_batch, 1, 28, 28)).astype(np.float32)
    exp = np.asarray(lenet_reference(params, x))

    vols = _volumes(PAPER_BATCH, params)

    c1 = lower(build_lenet(bench_batch)).optimize(
        [DeviceOffloadPass()]).compile("jnp")
    c1(x=x, **params)
    t0 = time.perf_counter()
    o1 = c1(x=x, **params)
    t_naive = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(o1["probs"]), exp, rtol=1e-2,
                               atol=1e-4)

    c2 = lower(build_lenet(bench_batch)).optimize(
        [InputToConstantPass(parameters=params), DeviceOffloadPass(),
         StreamingCompositionPass()]).compile("pallas")
    c2(x=x)
    t0 = time.perf_counter()
    o2 = c2(x=x)
    t_stream = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(o2["probs"]), exp, rtol=1e-2,
                               atol=1e-4)

    report("lenet_naive_volume_GiB", vols["naive"] / 2**30,
           "paper 0.28 GiB @ batch 1000 (incl. per-tile weight re-streams "
           "we don't model; see EXPERIMENTS §Paper)")
    report("lenet_const_volume_GiB", vols["const"] / 2**30,
           f"ratio {vols['naive']/vols['const']:.2f}x @1000; 1.20x @32 "
           f"(paper 1.27x)")
    report("lenet_stream_volume_GiB", vols["stream"] / 2**30,
           f"ratio {vols['naive']/vols['stream']:.2f}x (paper 1.7x; we "
           f"stream every intermediate)")
    report("lenet_naive_ms", t_naive * 1e3, f"batch={bench_batch} CPU jnp")
    report("lenet_stream_pallas_ms", t_stream * 1e3,
           f"fused {c2.report['fused_regions']}")

    # conv stack through halo-aware MapFusion: ONE grid kernel for
    # conv+relu+maxpool vs one kernel per stage vs the jnp lowering
    cb = 2 if small else CONV_BATCH
    xc = rng.standard_normal((cb, 1, IH, IH)).astype(np.float32)
    Wc = (rng.standard_normal((K, 1, R, R)) * 0.1).astype(np.float32)
    bc = (rng.standard_normal((K,)) * 0.1).astype(np.float32)
    ref = _convblock_reference(xc, Wc, bc)

    cf = lower(_convblock_sdfg(cb)).compile("pallas")
    assert len(cf.report["grid_kernels"]) == 1, \
        f"conv stack must be ONE grid kernel, got {cf.report['grid_kernels']}"
    blocks = cf.report["grid_converted"][0]["block_shape"]
    cp = lower(_convblock_sdfg(cb)).compile("pallas",
                                            pipeline=_perstage_pipeline())
    assert len(cp.report["grid_kernels"]) == 2, \
        f"per-stage conv stack must be 2 kernels, " \
        f"got {cp.report['grid_kernels']}"
    cj = lower(_convblock_sdfg(cb)).compile("jnp")

    def _best(fn):
        fn(x=xc, W=Wc, bias=bc)  # compile / warm
        best, out = float("inf"), None
        for _ in range(5):
            t0 = time.perf_counter()
            out = fn(x=xc, W=Wc, bias=bc)
            np.asarray(out["y"])
            best = min(best, time.perf_counter() - t0)
        return out, best

    of, tf = _best(cf)
    op, tp = _best(cp)
    oj, tj = _best(cj)
    np.testing.assert_allclose(np.asarray(of["y"]), ref, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(op["y"]), ref, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(oj["y"]), ref, rtol=1e-4,
                               atol=1e-5)
    report("lenet_convblock_fused_ms", tf * 1e3,
           f"batch={cb}; conv+relu+pool as ONE grid kernel (4 conv "
           f"replicas + pool, blocks={blocks}); {tp/tf:.2f}x vs per-stage",
           backend="pallas", grid_kernels=1, block_shape=blocks)
    report("lenet_convblock_perstage_ms", tp * 1e3,
           f"batch={cb}; conv and pool as separate grid kernels",
           backend="pallas", grid_kernels=2)
    report("lenet_convblock_jnp_ms", tj * 1e3,
           f"batch={cb}; structural vmap lowering")
    assert tf < tp, "fused conv stack must beat the per-stage baseline"
