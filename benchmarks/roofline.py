"""Format dry-run JSON results into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"


def load(paths=None):
    recs = []
    for p in paths or sorted(RESULTS.glob("dryrun*.json")):
        recs.extend(json.loads(Path(p).read_text()))
    # dedup (arch, shape, mesh, variant) keeping the last
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"],
              r.get("variant", "baseline"))] = r
    return list(seen.values())


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def roofline_table(recs, mesh="16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | HBM GiB/dev | useful frac | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped ({r.get('reason','')[:40]}…) | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR {r.get('error','')[:40]} | — | — | — |")
            continue
        t = r["roofline"]
        mem = r.get("memory", {}).get("total_hbm_bytes")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {fmt_bytes(mem)} | "
            f"{t.get('useful_fraction', 0):.3f} | "
            f"{t.get('roofline_fraction', 0):.4f} |")
    return "\n".join(rows)


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | status | lower s | compile s | "
            "HBM GiB/dev | collectives (AR/AG/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['status']} | — | — | — | — |")
            continue
        mem = r.get("memory", {}).get("total_hbm_bytes")
        c = r.get("collectives", {})
        cc = "/".join(str(c.get(k, {}).get("count", 0)) for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('lower_s','-')} | {r.get('compile_s','-')} | "
            f"{fmt_bytes(mem)} | {cc} |")
    return "\n".join(rows)


def main():
    recs = load(sys.argv[1:] or None)
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))


if __name__ == "__main__":
    main()
