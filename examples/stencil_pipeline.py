"""Paper §6 case study: StencilFlow program through the multi-level stack.

JSON program (Fig. 17, two diffusion iterations) -> stencil Library Nodes
-> DeviceOffload + StreamingComposition -> fused multi-stage Pallas kernel
(sliding-window VMEM slabs; the intermediate field never leaves VMEM).

Run: PYTHONPATH=src python examples/stencil_pipeline.py
"""
import numpy as np

import repro.kernels  # noqa: F401
from repro.frontends.stencil import build_stencil_program
from repro.kernels.stencil import stencil2d_ref
from repro.pipeline import (DeviceOffloadPass, StreamingCompositionPass,
                            lower)

PROGRAM = {
    "name": "diffusion_2it",
    "dimensions": [1024, 512],
    "outputs": ["d"],
    "inputs": {"a": {"data_type": "float32", "input_dims": ["j", "k"]}},
    "program": {
        "b": {"computation": "b = c0*a[j,k] + c1*a[j-1,k] + c2*a[j+1,k] + "
                             "c3*a[j,k-1] + c4*a[j,k+1]"},
        "d": {"computation": "d = c0*b[j,k] + c1*b[j-1,k] + c2*b[j+1,k] + "
                             "c3*b[j,k-1] + c4*b[j,k+1]"},
    },
}


def main():
    print("== parse JSON program ->", len(PROGRAM["program"]),
          "stencil operators")
    staged = lower(build_stencil_program(PROGRAM))
    staged.optimize([DeviceOffloadPass()])
    v0 = staged.sdfg.off_chip_volume()
    staged.optimize([StreamingCompositionPass()])
    n_comp = staged.reports[-1]["passes"][0]["summary"]
    v1 = staged.sdfg.off_chip_volume()
    print(f"== StreamingComposition: {n_comp} intermediate(s) -> streams; "
          f"volume {v0/2**20:.1f} -> {v1/2**20:.1f} MiB")

    c = staged.compile("pallas")
    print("== fused:", c.report["fused_regions"])

    rng = np.random.default_rng(0)
    a = rng.standard_normal(tuple(PROGRAM["dimensions"])).astype(np.float32)
    co = np.array([0.2, 0.1, 0.15, 0.25, 0.3], np.float32)
    out = np.asarray(c(a=a, b_coeffs=co, d_coeffs=co)["d"])
    offs = [(0, 0), (-1, 0), (1, 0), (0, -1), (0, 1)]
    exp = np.asarray(stencil2d_ref(stencil2d_ref(a, co, offs), co, offs))
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-5)
    print("== matches the unfused reference. OK")


if __name__ == "__main__":
    main()
