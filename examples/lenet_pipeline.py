"""Paper §5 case study: LeNet-5 inference ladder (Table 3).

naive -> InputToConstant -> StreamingComposition, driven through the
staged pipeline (Lowered.optimize with pass pipelines) and compiled with
the Pallas backend (conv+pool stages fuse into im2col systolic GEMMs).

Run: PYTHONPATH=src python examples/lenet_pipeline.py
"""
import time

import numpy as np

import repro.kernels  # noqa: F401
from repro.frontends.ml import build_lenet, init_lenet_params, lenet_reference
from repro.pipeline import (DeviceOffloadPass, InputToConstantPass,
                            StreamingCompositionPass, lower)


def main():
    batch = 100
    params = init_lenet_params()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((batch, 1, 28, 28)).astype(np.float32)
    expected = np.asarray(lenet_reference(params, x))

    print("== naive (all parameters and intermediates off-chip)")
    l1 = lower(build_lenet(batch)).optimize([DeviceOffloadPass()])
    print(f"   off-chip volume: {l1.sdfg.off_chip_volume()/2**20:.2f} MiB")
    out = l1.compile("jnp")(x=x, **params)
    np.testing.assert_allclose(np.asarray(out["probs"]), expected,
                               rtol=1e-2, atol=1e-4)

    print("== InputToConstant (paper: parameters fixed in hardware)")
    l2 = lower(build_lenet(batch)).optimize(
        [InputToConstantPass(parameters=params), DeviceOffloadPass()])
    v_const = l2.sdfg.off_chip_volume()
    print(f"   off-chip volume: {v_const/2**20:.2f} MiB")

    print("== + StreamingComposition, Pallas backend")
    l2.optimize([StreamingCompositionPass()])
    v_stream = l2.sdfg.off_chip_volume()
    c = l2.compile("pallas")
    t0 = time.perf_counter()
    out = c(x=x)
    dt = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(out["probs"]), expected,
                               rtol=1e-2, atol=1e-4)
    print(f"   off-chip volume: {v_stream/2**20:.2f} MiB")
    print(f"   fused pipelines: {c.report['fused_regions']}")
    print(f"   inference time (CPU, interpret): {dt*1e3:.1f} ms "
          f"for batch {batch}")
    print("OK")


if __name__ == "__main__":
    main()
