"""Serving driver: batched greedy decoding with a KV cache.

Run: PYTHONPATH=src python examples/serve_lm.py --arch granite-3-2b --tokens 64
(uses the reduced config on CPU; the full config is exercised by the
multi-pod dry-run.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.tokens
    cache = model.init_cache(args.batch, max_seq)

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len),
                                      dtype=np.int32))

    step = jax.jit(model.decode_step, donate_argnums=(1,))

    # prefill token-by-token (chunked prefill is the production path)
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t:t + 1])

    # batched greedy decode
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0

    seq = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * (args.tokens - 1) / dt
    print(f"arch={args.arch} (reduced) batch={args.batch}")
    print(f"generated {seq.shape[1]} tokens/seq; throughput {tps:.1f} tok/s "
          f"(CPU)")
    print("first sequence:", seq[0][:16], "...")


if __name__ == "__main__":
    main()
