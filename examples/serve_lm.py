"""Serving driver: continuous batching over the compiled decode step.

Run: PYTHONPATH=src python examples/serve_lm.py --arch starcoder2-3b
(uses the reduced config on CPU; the full config is exercised by the
multi-pod dry-run.)

Requests with mixed prompt/output lengths stream through the
:class:`repro.serving.Scheduler`: chunked prefill, paged KV cache with a
per-slot block table, and one (B, ctx)-bucketed SDFG-compiled decode
step per iteration — the per-layer attention runs as Pallas grid
kernels inside it. Prints per-request latency, the compiled-step report
(grid kernels vs fallbacks), and the compilation-cache hit rate.

Fault-tolerance modes (ISSUE 8):

* ``--faults`` arms a :class:`repro.serving.ServeFaultPlan` combining a
  step exception, forced page pressure (>= 1 preemption), and a NaN
  logits step, then asserts every request finished with a typed reason
  and that the greedy token streams are byte-identical to a fault-free
  run — the CI fault-injection smoke.
* ``--snapshot-at N`` snapshots mid-decode after N steps, restores into
  a fresh scheduler, and asserts the resumed streams match.
* ``--small`` shrinks everything for CI wall-clock.

Elastic multi-host mode (ISSUE 9): ``--cluster-sim --shrink-at N`` runs
the decode step sharded across a 2-host mesh (shard_map over the
ShardMapPass-partitioned SDFG), shrinks the mesh to 1 host after N
steps mid-decode — preempting the requests living on the dropped
shard — and asserts the greedy streams stay byte-identical to an
unsharded run, with typed ``shrink_preempt``/``mesh_shrink`` events.
"""
import argparse
import os
import sys
import time

# device count is fixed at jax import: simulate the hosts first
if "--cluster-sim" in sys.argv:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.pipeline.cache import COMPILATION_CACHE
from repro.serving import FaultInjector, Scheduler, ServeFaultPlan


def build(args, cfg, model, params, injector=None):
    n_pages = args.slots * (args.max_model_len // args.page_size) + 1
    return Scheduler(model, params, max_slots=args.slots,
                     page_size=args.page_size, n_pages=n_pages,
                     max_model_len=args.max_model_len,
                     cache_dtype=args.cache_dtype, injector=injector)


def submit_all(sched, cfg, args):
    rng = np.random.default_rng(0)
    for _ in range(args.requests):  # mixed lengths: continuous batching
        plen = int(rng.integers(4, min(32, args.max_model_len // 2)))
        new = int(rng.integers(4, args.tokens + 1))
        sched.submit(list(rng.integers(0, cfg.vocab, plen)), new)


def streams(reqs):
    return {r.rid: list(r.tokens_out) for r in reqs}


def run_cluster_sim(args, cfg, model, params):
    """Sharded decode across 2 simulated hosts + live mesh shrink."""
    kw = dict(max_slots=4, page_size=4, n_pages=16, max_model_len=16,
              prefill_chunk=4, cache_dtype="float32")
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab,
                                          int(rng.integers(2, 6)))))
               for _ in range(4)]

    def submit(s):
        for p in prompts:
            s.submit(p, 6)

    base = Scheduler(model, params, **kw)
    submit(base)
    baseline = streams(base.run())
    base.check_invariants()

    sh = Scheduler(model, params, n_shards=2, **kw)
    submit(sh)
    out = streams(sh.run())
    sh.check_invariants()
    assert out == baseline, "sharded streams diverged from unsharded"
    print(f"2-shard mesh: {len(out)} requests byte-identical to the "
          f"unsharded run (mesh {sh.stats()['mesh_signature'][:48]}...)")

    s = Scheduler(model, params, n_shards=2, **kw)
    submit(s)
    for _ in range(args.shrink_at):
        s.step()
    s.shrink(1)
    evs = [e for e in s.events
           if e["kind"] in ("mesh_shrink", "shrink_preempt")]
    print("shrink events:", [(e["kind"], e.get("rid")) for e in evs])
    assert any(e["kind"] == "mesh_shrink" for e in evs)
    out = streams(s.run())
    s.check_invariants()
    assert out == baseline, "streams diverged after the mesh shrink"
    preempted = [e["rid"] for e in evs if e["kind"] == "shrink_preempt"]
    print(f"shrink at step {args.shrink_at}: preempted rids {preempted} "
          f"recomputed; all streams byte-identical after 2 -> 1 hosts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24,
                    help="max new tokens per request")
    ap.add_argument("--max-model-len", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--cache-dtype", default="bfloat16")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized run (fewer slots/requests/tokens)")
    ap.add_argument("--faults", action="store_true",
                    help="inject exception+pressure+NaN; assert recovery")
    ap.add_argument("--snapshot-at", type=int, default=None, metavar="N",
                    help="snapshot after N steps, restore, assert "
                         "token-exact resume")
    ap.add_argument("--cluster-sim", action="store_true",
                    help="shard the decode step across 2 simulated "
                         "hosts; assert byte-identical streams")
    ap.add_argument("--shrink-at", type=int, default=3, metavar="N",
                    help="cluster-sim: shrink the mesh 2 -> 1 after N "
                         "steps")
    args = ap.parse_args()
    if args.small:
        args.requests = min(args.requests, 6)
        args.slots = min(args.slots, 4)
        args.tokens = min(args.tokens, 8)
        args.max_model_len = min(args.max_model_len, 64)
        args.page_size = min(args.page_size, 8)

    cfg = get_config(args.arch).reduced()
    if args.cluster_sim:
        import dataclasses
        cfg = dataclasses.replace(cfg, activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.cluster_sim:
        run_cluster_sim(args, cfg, model, params)
        return

    baseline = None
    if args.faults or args.snapshot_at is not None:
        base_sched = build(args, cfg, model, params)
        submit_all(base_sched, cfg, args)
        baseline = streams(base_sched.run())
        base_sched.check_invariants()
        print(f"fault-free baseline: {len(baseline)} requests, "
              f"{sum(map(len, baseline.values()))} tokens")

    injector = None
    if args.faults:
        plan = ServeFaultPlan(step_exception_at=1, page_pressure_at=2,
                              page_pressure_release_at=8, nan_logits_at=5)
        injector = FaultInjector(plan)
    sched = build(args, cfg, model, params, injector=injector)
    submit_all(sched, cfg, args)

    if args.snapshot_at is not None:
        for _ in range(args.snapshot_at):
            sched.step()
        snap = sched.snapshot()
        resumed = build(args, cfg, model, params).restore(snap)
        out = streams(resumed.run())
        resumed.check_invariants()
        assert out == baseline, "restored run diverged from baseline"
        print(f"snapshot at step {args.snapshot_at}: restored run is "
              "token-exact")

    t0 = time.perf_counter()
    reqs = sched.run()
    wall = time.perf_counter() - t0
    sched.check_invariants()

    total = sum(len(r.tokens_out) for r in reqs)
    print(f"arch={args.arch} (reduced) slots={args.slots} "
          f"requests={args.requests}")
    print(f"{total} tokens in {wall:.2f}s -> {total / wall:.1f} tok/s "
          f"({sched.n_decode_steps} decode steps)\n")
    print(f"{'rid':>4} {'prompt':>7} {'new':>4} {'reason':>10} "
          f"{'ttft_ms':>8} {'p50_ms':>7} {'p99_ms':>7}")
    for r in reqs:
        steady = r.token_times[1:] or r.token_times
        print(f"{r.rid:>4} {len(r.prompt):>7} {len(r.tokens_out):>4} "
              f"{r.finish_reason:>10} {r.ttft * 1e3:>8.1f} "
              f"{np.percentile(steady, 50) * 1e3:>7.2f} "
              f"{np.percentile(steady, 99) * 1e3:>7.2f}")

    if args.faults:
        st = sched.stats()
        print("\nfault recovery:", {k: st[k] for k in
                                    ("preemptions", "fallback_steps",
                                     "recomputes")})
        print("injected:", [e["kind"] for e in injector.events])
        print("watchdog:", [e["kind"] for e in st["watchdog_events"]])
        assert st["preemptions"] >= 1, "page pressure caused no preemption"
        assert all(r.finish_reason for r in reqs), "untyped finish"
        out = streams(reqs)
        assert out == baseline, "faulted streams diverged from fault-free"
        print("faulted run recovered: streams byte-identical to "
              "fault-free baseline")

    print("\ncompiled (B, ctx) buckets:", sorted(sched.compiler._steps))
    for (B, ctx), step in sorted(sched.compiler._steps.items()):
        rep = step.report
        print(f"  ({B}, {ctx}): grid_kernels={rep.get('grid_kernels')} "
              f"fallbacks={rep.get('grid_fallbacks')} rung={step.rung}")
    stats = COMPILATION_CACHE.stats
    print(f"compilation cache: {stats['hits']} hits / "
          f"{stats['misses']} misses ({stats['entries']} entries)")


if __name__ == "__main__":
    main()
