"""Serving driver: continuous batching over the compiled decode step.

Run: PYTHONPATH=src python examples/serve_lm.py --arch starcoder2-3b
(uses the reduced config on CPU; the full config is exercised by the
multi-pod dry-run.)

Requests with mixed prompt/output lengths stream through the
:class:`repro.serving.Scheduler`: chunked prefill, paged KV cache with a
per-slot block table, and one (B, ctx)-bucketed SDFG-compiled decode
step per iteration — the per-layer attention runs as Pallas grid
kernels inside it. Prints per-request latency, the compiled-step report
(grid kernels vs fallbacks), and the compilation-cache hit rate.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.pipeline.cache import COMPILATION_CACHE
from repro.serving import Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24,
                    help="max new tokens per request")
    ap.add_argument("--max-model-len", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_pages = args.slots * (args.max_model_len // args.page_size) + 1
    sched = Scheduler(model, params, max_slots=args.slots,
                      page_size=args.page_size, n_pages=n_pages,
                      max_model_len=args.max_model_len)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):  # mixed lengths: continuous batching
        plen = int(rng.integers(4, 32))
        new = int(rng.integers(4, args.tokens + 1))
        sched.submit(list(rng.integers(0, cfg.vocab, plen)), new)

    t0 = time.perf_counter()
    reqs = sched.run()
    wall = time.perf_counter() - t0
    sched.check_invariants()

    total = sum(len(r.tokens_out) for r in reqs)
    print(f"arch={args.arch} (reduced) slots={args.slots} "
          f"requests={args.requests}")
    print(f"{total} tokens in {wall:.2f}s -> {total / wall:.1f} tok/s "
          f"({sched.n_steps} decode steps)\n")
    print(f"{'rid':>4} {'prompt':>7} {'new':>4} {'ttft_ms':>8} "
          f"{'p50_ms':>7} {'p99_ms':>7}")
    for r in reqs:
        steady = r.token_times[1:] or r.token_times
        print(f"{r.rid:>4} {len(r.prompt):>7} {len(r.tokens_out):>4} "
              f"{r.ttft * 1e3:>8.1f} "
              f"{np.percentile(steady, 50) * 1e3:>7.2f} "
              f"{np.percentile(steady, 99) * 1e3:>7.2f}")

    print("\ncompiled (B, ctx) buckets:", sorted(sched.compiler._steps))
    for (B, ctx), step in sorted(sched.compiler._steps.items()):
        rep = step.report
        print(f"  ({B}, {ctx}): grid_kernels={rep.get('grid_kernels')} "
              f"fallbacks={rep.get('grid_fallbacks')}")
    stats = COMPILATION_CACHE.stats
    print(f"compilation cache: {stats['hits']} hits / "
          f"{stats['misses']} misses ({stats['entries']} entries)")


if __name__ == "__main__":
    main()
