"""Quickstart: the paper's §3 multi-level flow on AXPYDOT (Figs. 9-13),
expressed through the staged AOT pipeline (ARCHITECTURE.md):

    Wrapped --lower()--> Lowered --optimize(passes)--> Lowered
            --compile(backend)--> Compiled

Build via the Python/BLAS frontend -> offload to device -> stream memory
accesses -> compose pipelines -> compile with both 'vendor' backends
(XLA-auto and Pallas-explicit) and compare; a second compile of the same
program is served from the compilation cache.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.kernels  # noqa: F401  (register fused kernels)
from repro.frontends import blas
from repro.frontends.api import dc_program
from repro.pipeline import (COMPILATION_CACHE, PassManager,
                            DeviceOffloadPass, StreamingCompositionPass,
                            StreamingMemoryPass, VectorizationPass)


@dc_program
def axpydot(p, n):
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))


def main():
    n = 1 << 20
    rng = np.random.default_rng(0)
    a = np.float32(0.7)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    expected = float(np.dot((a * x + y).astype(np.float32), w))

    print("== 1. trace: Wrapped -> Lowered (generic SDFG, paper Fig. 10)")
    lowered = axpydot.lower(n)
    print("  ", lowered)

    print("== 2. DeviceOffload pass (paper Fig. 11, FPGATransformSDFG)")
    lowered.optimize([DeviceOffloadPass()])
    naive_vol = lowered.sdfg.off_chip_volume()
    print(f"   off-chip volume: {naive_vol/2**20:.1f} MiB")

    print("== 3. Vectorization + StreamingComposition + StreamingMemory "
          "(paper Fig. 12)")
    mid = PassManager([VectorizationPass(width=128),
                       StreamingCompositionPass(),
                       StreamingMemoryPass()], name="streaming_ladder")
    lowered.optimize(mid)
    stream_vol = lowered.sdfg.off_chip_volume()
    main_state = [s for s in lowered.sdfg.states if s.label == "main"][0]
    for entry in lowered.reports[-1]["passes"]:
        print(f"   pass {entry['name']:22s} applied={entry['summary']} "
              f"({entry['seconds']*1e3:.1f} ms)")
    print(f"   off-chip volume: {stream_vol/2**20:.1f} MiB "
          f"({naive_vol/stream_vol:.2f}x less; z never leaves VMEM)")
    print(f"   processing elements in kernel state: "
          f"{len(main_state.processing_elements())}")

    print("== 4. compile with both vendor backends (default pipelines)")
    for backend in ("jnp", "pallas"):
        staged = axpydot.lower(n).optimize(
            [DeviceOffloadPass(), StreamingCompositionPass()])
        c = staged.compile(backend)
        out = float(np.asarray(c(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
        fused = c.report["fused_regions"]
        print(f"   backend={backend:7s} result={out:+.4f} "
              f"(expected {expected:+.4f}) fused={fused}")

    print("== 5. recompile: served from the compilation cache")
    before = COMPILATION_CACHE.stats
    axpydot.lower(n).optimize(
        [DeviceOffloadPass(), StreamingCompositionPass()]).compile("pallas")
    after = COMPILATION_CACHE.stats
    assert after["hits"] == before["hits"] + 1, (before, after)
    print(f"   cache: {after}")
    print("OK")


if __name__ == "__main__":
    main()
