"""Quickstart: the paper's §3 multi-level flow on AXPYDOT (Figs. 9-13).

Build via the Python/BLAS frontend -> offload to device -> stream memory
accesses -> compose pipelines -> compile with both 'vendor' backends
(XLA-auto and Pallas-explicit) and compare.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.kernels  # noqa: F401  (register fused kernels)
from repro.frontends import blas
from repro.frontends.api import Program
from repro.transforms import (DeviceOffload, StreamingComposition,
                              StreamingMemory, Vectorization)


def build(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


def main():
    n = 1 << 20
    rng = np.random.default_rng(0)
    a = np.float32(0.7)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    expected = float(np.dot((a * x + y).astype(np.float32), w))

    print("== 1. frontend emits the generic SDFG (paper Fig. 10)")
    sdfg = build(n)
    print("  ", sdfg)

    print("== 2. DeviceOffload (paper Fig. 11, FPGATransformSDFG)")
    sdfg.apply(DeviceOffload)
    naive_vol = sdfg.off_chip_volume()
    print(f"   off-chip volume: {naive_vol/2**20:.1f} MiB")

    print("== 3. Vectorization + StreamingComposition + StreamingMemory "
          "(paper Fig. 12)")
    sdfg.apply(Vectorization, width=128)
    nc = sdfg.apply(StreamingComposition)
    nm = sdfg.apply(StreamingMemory)
    stream_vol = sdfg.off_chip_volume()
    main_state = [s for s in sdfg.states if s.label == "main"][0]
    print(f"   compositions={nc} memory-streams={nm}")
    print(f"   off-chip volume: {stream_vol/2**20:.1f} MiB "
          f"({naive_vol/stream_vol:.2f}x less; z never leaves VMEM)")
    print(f"   processing elements in kernel state: "
          f"{len(main_state.processing_elements())}")

    print("== 4. compile with both vendor backends")
    for backend in ("jnp", "pallas"):
        s = build(n)
        s.apply(DeviceOffload)
        s.apply(StreamingComposition)
        c = s.compile(backend)
        out = float(np.asarray(c(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
        fused = c.report["fused_regions"]
        print(f"   backend={backend:7s} result={out:+.4f} "
              f"(expected {expected:+.4f}) fused={fused}")
    print("OK")


if __name__ == "__main__":
    main()
