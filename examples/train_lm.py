"""End-to-end training driver: ~100M-parameter LM for a few hundred steps
with checkpoint/restart, using the production trainer substrate.

Run:   PYTHONPATH=src python examples/train_lm.py --steps 200
Resume: rerun the same command — it restores the latest checkpoint.

Elastic multi-host mode (ISSUE 9):

    PYTHONPATH=src python examples/train_lm.py --cluster-sim --hosts 4 \\
        --die-at 6

drives the REAL sharded compiled step (ShardMapPass over the
data-parallel gradient SDFG) through a SimulatedCluster: host 1 dies at
the given step, the latest per-host sharded checkpoint restores onto
the shrunken mesh (a compilation-cache miss recompile), and the run
asserts the loss curve is identical to an uninterrupted run.
"""
import argparse
import os
import sys

# device count is fixed at jax import: simulate the hosts before any
# repro import pulls jax in
if "--cluster-sim" in sys.argv:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import Trainer, TrainerConfig

# ~100M params: 12L x d=640 x ffn 2560, 10 heads, 32k vocab
LM100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=640, n_heads=10,
    n_kv_heads=10, d_head=64, d_ff=2560, vocab=32768, tie_embeddings=True,
    activation_dtype="float32",
)


def run_cluster_sim(args):
    import shutil
    from repro.pipeline.cache import CompilationCache
    from repro.runtime import FaultPlan, run_elastic_training

    cfg = dataclasses.replace(LM100M.reduced(),
                              activation_dtype="float32")
    steps = min(args.steps, 10)
    gb, seq = 4, 16
    kw = dict(n_steps=steps, seq_len=seq, global_batch=gb,
              checkpoint_every=2)
    for d in (args.ckpt_dir + "-base", args.ckpt_dir + "-elastic"):
        shutil.rmtree(d, ignore_errors=True)
    print(f"cluster-sim: {args.hosts} hosts, host 1 dies at step "
          f"{args.die_at}, {steps} steps, batch {gb}")
    base = run_elastic_training(cfg, n_hosts=args.hosts,
                                ckpt_dir=args.ckpt_dir + "-base",
                                cache=CompilationCache(max_entries=8), **kw)
    plan = FaultPlan(die_at_step=args.die_at, die_host=1)
    el = run_elastic_training(cfg, n_hosts=args.hosts,
                              ckpt_dir=args.ckpt_dir + "-elastic",
                              plan=plan,
                              cache=CompilationCache(max_entries=8), **kw)
    sim = el["sim"]
    print("restarts:", sim["restarts"])
    print("wasted_steps:", sim["wasted_steps"])
    print("reshards:", [(r["n_hosts"], r["n_shards"])
                        for r in el["reshards"]])
    assert sim["restarts"], "the planned host death never fired"
    assert len(el["reshards"]) == 2, "no mesh shrink after the death"
    assert el["reshards"][1]["n_shards"] < el["reshards"][0]["n_shards"]
    worst = 0.0
    for step in sorted(base["losses"]):
        d = abs(base["losses"][step] - el["losses"][step])
        worst = max(worst, d)
        print(f"step {step}: base {base['losses'][step]:.6f} "
              f"elastic {el['losses'][step]:.6f} (d={d:.2e})")
    assert worst < 1e-4, (
        f"loss curve diverged after elastic recovery (max diff {worst:.2e})")
    print(f"elastic recovery is loss-curve-identical "
          f"(max diff {worst:.2e}, wasted_steps={sim['wasted_steps']})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced model for CI-speed runs")
    ap.add_argument("--cluster-sim", action="store_true",
                    help="elastic multi-host run: sharded step + host "
                         "death + loss-curve-exact recovery")
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--die-at", type=int, default=6,
                    help="cluster-sim: step at which host 1 dies")
    args = ap.parse_args()

    if args.cluster_sim:
        run_cluster_sim(args)
        return

    cfg = LM100M.reduced() if args.tiny else LM100M
    print(f"model: {cfg.name} ~{cfg.n_params()/1e6:.0f}M params")
    tcfg = TrainerConfig(steps=args.steps, checkpoint_every=50,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, make_smoke_mesh(), tcfg, seq_len=args.seq,
                      global_batch=args.batch)

    def on_step(step, metrics):
        if step % 10 == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f}")

    out = trainer.run(on_step)
    losses = [m["loss"] for m in out["log"]]
    if losses:
        print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
        print(f"mean step time {sum(m['s'] for m in out['log'])/len(losses):.3f}s")
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
