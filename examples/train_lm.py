"""End-to-end training driver: ~100M-parameter LM for a few hundred steps
with checkpoint/restart, using the production trainer substrate.

Run:   PYTHONPATH=src python examples/train_lm.py --steps 200
Resume: rerun the same command — it restores the latest checkpoint.
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import Trainer, TrainerConfig

# ~100M params: 12L x d=640 x ffn 2560, 10 heads, 32k vocab
LM100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=640, n_heads=10,
    n_kv_heads=10, d_head=64, d_ff=2560, vocab=32768, tie_embeddings=True,
    activation_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced model for CI-speed runs")
    args = ap.parse_args()

    cfg = LM100M.reduced() if args.tiny else LM100M
    print(f"model: {cfg.name} ~{cfg.n_params()/1e6:.0f}M params")
    tcfg = TrainerConfig(steps=args.steps, checkpoint_every=50,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, make_smoke_mesh(), tcfg, seq_len=args.seq,
                      global_batch=args.batch)

    def on_step(step, metrics):
        if step % 10 == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f}")

    out = trainer.run(on_step)
    losses = [m["loss"] for m in out["log"]]
    if losses:
        print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")
        print(f"mean step time {sum(m['s'] for m in out['log'])/len(losses):.3f}s")
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
